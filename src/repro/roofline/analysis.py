"""Roofline analysis: aggregate dry-run artifacts into §Roofline tables.

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = collective_bytes / (chips x 50 GB/s link)

HLO_FLOPs/bytes come from compiled.cost_analysis() on the per-device
partitioned module with scan bodies un-counted, corrected by the two-point
unrolled extrapolation (see launch/dryrun.py); collective bytes are parsed
from the optimized HLO.  MODEL_FLOPS = 6*N_active*T (train) / 2*N_active*T.

Methodology caveats (documented for honesty):
  * 'bytes accessed' counts every HLO op's operand bytes pre-fusion on the
    CPU backend -- an upper bound on real HBM traffic.  The memory term is
    therefore conservative; relative comparisons across plans remain valid.
  * the collective term charges each op's full payload to one link hop
    (no ring-step modelling): collective_bytes / (chips * link_bw).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.core.materializer import GB

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def load_cells(art_dir: Optional[str] = None) -> List[Dict]:
    art_dir = art_dir or os.path.abspath(ARTIFACT_DIR)
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def bottleneck_advice(cell: Dict) -> str:
    """One sentence: what would move the dominant term down."""
    r = cell.get("roofline", {})
    plan = cell.get("plan", {})
    dom = r.get("dominant")
    shape = cell.get("shape", "")
    if dom == "compute":
        if r.get("useful_flops_ratio", 1) < 0.6:
            return ("compute-bound with low useful-FLOPs ratio: cut remat "
                    "recompute (selective policies) and causal-masked waste "
                    "(block-skipping flash kernel)")
        return ("compute-bound near useful FLOPs: gains need larger "
                "per-chip tiles (less TP) or lower precision (int8/fp8)")
    if dom == "memory":
        if "decode" in shape:
            return ("memory-bound on KV reads: quantize KV to int8, or "
                    "widen batch per chip to amortize weight streaming")
        return ("memory-bound: increase fusion (Pallas), reduce remat "
                "re-reads, or shrink activation dtype")
    if dom == "collective":
        if plan.get("ep"):
            return ("collective-bound on the MoE combine: replace psum with "
                    "all-to-all dispatch (bytes / num_experts) and overlap "
                    "with expert GEMMs")
        if plan.get("fsdp"):
            return ("collective-bound on FSDP all-gathers: prefetch next "
                    "layer's params during compute (overlap), or shift to "
                    "ZeRO-1 + TP")
        return ("collective-bound: overlap gradient reduce-scatter with "
                "backward compute; compress cross-pod traffic (int8)")
    return "n/a"


def roofline_table(cells: List[Dict], mesh: str = "single_pod"
                   ) -> List[Dict]:
    rows = []
    for c in cells:
        if c.get("status") != "ok" or c.get("mesh") != mesh:
            continue
        r = c["roofline"]
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "compute_s": r["compute_term_s"],
            "memory_s": r["memory_term_s"],
            "collective_s": r["collective_term_s"],
            "dominant": r["dominant"],
            "model_flops": r["model_flops"],
            "useful_ratio": r["useful_flops_ratio"],
            "mfu_ub": r["mfu_upper_bound"],
            "fits": c["fits"],
            "peak_gib": c["memory"].get("peak_tpu_adjusted", c["memory"]["peak_bytes"]) / GB,
            "advice": bottleneck_advice(c),
            "plan": {k: c["plan"][k] for k in
                     ("tp", "ep", "fsdp", "zero", "remat", "microbatch",
                      "attn_impl", "kv_shard_heads", "kv_shard_seq",
                      "batch_axes", "seq_axes")},
        })
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "MODEL/HLO | MFU-UB | peak GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_ub']:.3f} | {r['peak_gib']:.2f} | "
            f"{'Y' if r['fits'] else 'N'} |")
    return hdr + "\n".join(lines) + "\n"


def summarize(art_dir: Optional[str] = None) -> Dict:
    cells = load_cells(art_dir)
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    failed = [c for c in cells if c.get("status") == "error"]
    fits = [c for c in ok if c.get("fits")]
    return {
        "total": len(cells), "ok": len(ok), "skipped": len(skipped),
        "failed": len(failed), "fits": len(fits),
        "failed_cells": [(c["arch"], c["shape"], c["mesh"],
                          c.get("error", "")) for c in failed],
        "over_budget": [(c["arch"], c["shape"], c["mesh"],
                         round(c["memory"].get("peak_tpu_adjusted",
                               c["memory"]["peak_bytes"]) / GB, 2))
                        for c in ok if not c.get("fits")],
    }


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--art-dir", default=None)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    cells = load_cells(args.art_dir)
    rows = roofline_table(cells, args.mesh)
    if args.markdown:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:12s} dom={r['dominant']:10s} "
                  f"cmp={_fmt_s(r['compute_s']):>8s} mem={_fmt_s(r['memory_s']):>8s} "
                  f"col={_fmt_s(r['collective_s']):>8s} mfu_ub={r['mfu_ub']:.3f} "
                  f"useful={r['useful_ratio']:.2f} fits={r['fits']}")
    print(json.dumps(summarize(args.art_dir), indent=1))


if __name__ == "__main__":
    main()
