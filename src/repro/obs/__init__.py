"""repro.obs -- off-by-default observability for the serving planes.

Three pieces (see docs/observability.md):

* :mod:`repro.obs.trace` -- bounded ring-buffer :class:`Tracer` of
  typed span/instant events across request lifecycle, pool
  arbitration, compiles, and autoscale decisions;
* :mod:`repro.obs.metrics` -- fixed-bucket :class:`Histogram` +
  :class:`MetricsRegistry` with Prometheus text exposition;
* :mod:`repro.obs.export` / :mod:`repro.obs.summary` -- Chrome
  trace-event JSON / JSONL exporters and the ``python -m repro.obs``
  trace summarizer;
* :mod:`repro.obs.http` -- a stdlib streaming ``/metrics`` listener
  (Prometheus text exposition) for pull-based scraping.

Everything is a no-op until :func:`enable` / :func:`enable_metrics` is
called; instrumentation sites pay one module-attribute read + ``None``
check when disabled.
"""

from .trace import (  # noqa: F401
    DEFAULT_CAPACITY, Tracer, current, disable, enable,
)
from .metrics import (  # noqa: F401
    LATENCY_BOUNDS, OCCUPANCY_BOUNDS, Histogram, MetricsRegistry,
    current_metrics, disable_metrics, enable_metrics, hist_delta,
    hist_merge,
)
from .export import (  # noqa: F401
    load_events, to_chrome_events, write_chrome_trace, write_jsonl,
)
from .http import MetricsServer, serve_metrics  # noqa: F401
from .summary import request_lifecycles, summarize  # noqa: F401
