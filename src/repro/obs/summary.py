"""Offline trace analysis: the ``python -m repro.obs`` summarizer.

Consumes either export format (Chrome trace JSON or JSONL, via
``export.load_events``) and reconstructs the per-request story the ring
buffer captured:

* **lifecycle table** -- per request: submit -> admit (queue wait) ->
  prefill (span + chunk count) -> first token (TTFT) -> finish, with
  preempt/park counts;
* **percentile tables** -- p50/p95/p99 of TTFT, queue wait, decode-step
  latency, and decode batch occupancy;
* **slowest-request drill-down** -- the full ordered event sequence of
  the worst-TTFT request with inter-event deltas (its critical path).

Pure stdlib, pure offline: nothing here is a hot-path API.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence


def pctl(values: Sequence[float], p: float) -> float:
    """Nearest-rank percentile on raw samples (exact, unlike the
    fixed-bucket Histogram approximation)."""
    if not values:
        return 0.0
    s = sorted(values)
    idx = min(len(s) - 1, max(0, int(round(p / 100.0 * len(s) + 0.5)) - 1))
    return s[idx]


def request_lifecycles(events: List[Dict]) -> Dict[str, Dict]:
    """Fold request-cat events into one record per request id."""
    reqs: Dict[str, Dict] = {}

    def rec(scope: str) -> Dict:
        r = reqs.get(scope)
        if r is None:
            r = {"req": scope, "submit": None, "admit": None,
                 "queue_wait": None, "prefill_dur": 0.0, "chunks": 0,
                 "ttft": None, "finish": None, "tokens": None,
                 "preempts": 0, "parks": 0, "unparks": 0,
                 "rejected": False, "events": []}
            reqs[scope] = r
        return r

    for e in sorted(events, key=lambda e: e["ts"]):
        if e["cat"] != "request" or not e.get("scope"):
            continue
        r = rec(e["scope"])
        r["events"].append(e)
        name, args = e["name"], e.get("args") or {}
        if name == "submit":
            r["submit"] = e["ts"]
        elif name == "admit":
            r["admit"] = e["ts"]
            r["queue_wait"] = args.get("queue_wait_s")
        elif name == "reject":
            r["rejected"] = True
        elif name == "prefill":
            r["prefill_dur"] += e.get("dur", 0.0)
        elif name == "prefill_chunk":
            r["chunks"] += 1
        elif name == "first_token":
            r["ttft"] = args.get("ttft_s")
        elif name == "preempt":
            r["preempts"] += 1
        elif name == "park":
            r["parks"] += 1
        elif name == "unpark":
            r["unparks"] += 1
        elif name == "finish":
            r["finish"] = e["ts"]
            r["tokens"] = args.get("tokens")
    return reqs


def decode_steps(events: List[Dict]) -> List[Dict]:
    return [e for e in events
            if e["cat"] == "engine" and e["name"] == "decode_step"]


def _fmt_ms(v: Optional[float]) -> str:
    return f"{v * 1e3:9.3f}" if v is not None else "        -"


def _pct_row(label: str, values: Sequence[float], unit: str = "ms") -> str:
    scale = 1e3 if unit == "ms" else 1.0
    return (f"  {label:<24} n={len(values):<6} "
            f"p50={pctl(values, 50) * scale:9.3f} "
            f"p95={pctl(values, 95) * scale:9.3f} "
            f"p99={pctl(values, 99) * scale:9.3f} {unit}")


def summarize(events: List[Dict]) -> str:
    """The full human-readable report for a trace file."""
    lines: List[str] = []
    reqs = request_lifecycles(events)
    done = [r for r in reqs.values() if not r["rejected"]]
    rejected = [r for r in reqs.values() if r["rejected"]]
    steps = decode_steps(events)

    lines.append("== trace summary ==")
    lines.append(f"  events: {len(events)}   requests: {len(reqs)} "
                 f"({len(rejected)} rejected)   decode steps: {len(steps)}")
    by_cat: Dict[str, int] = {}
    for e in events:
        by_cat[e["cat"]] = by_cat.get(e["cat"], 0) + 1
    lines.append("  by category: " + "  ".join(
        f"{c}={n}" for c, n in sorted(by_cat.items())))

    # -- percentile tables ---------------------------------------------------
    ttfts = [r["ttft"] for r in done if r["ttft"] is not None]
    waits = [r["queue_wait"] for r in done if r["queue_wait"] is not None]
    step_durs = [e["dur"] for e in steps]
    batches = [float((e.get("args") or {}).get("batch", 0)) for e in steps]
    lines.append("")
    lines.append("== latency percentiles ==")
    lines.append(_pct_row("ttft", ttfts))
    lines.append(_pct_row("queue_wait", waits))
    lines.append(_pct_row("decode_step", step_durs))
    lines.append(_pct_row("batch_occupancy", batches, unit="reqs"))

    # -- per-request lifecycle table -----------------------------------------
    lines.append("")
    lines.append("== requests ==")
    lines.append(f"  {'req':<12} {'queue_ms':>9} {'prefill_ms':>10} "
                 f"{'chunks':>6} {'ttft_ms':>9} {'e2e_ms':>9} "
                 f"{'toks':>5} {'pre':>3} {'park':>4}")
    for r in sorted(done, key=lambda r: r["submit"] or 0.0):
        e2e = (r["finish"] - r["submit"]
               if r["finish"] is not None and r["submit"] is not None
               else None)
        lines.append(
            f"  {r['req']:<12} {_fmt_ms(r['queue_wait'])} "
            f"{r['prefill_dur'] * 1e3:10.3f} {r['chunks']:>6} "
            f"{_fmt_ms(r['ttft'])} {_fmt_ms(e2e)} "
            f"{r['tokens'] if r['tokens'] is not None else '-':>5} "
            f"{r['preempts']:>3} {r['parks']:>4}")

    # -- slowest-request drill-down ------------------------------------------
    with_ttft = [r for r in done if r["ttft"] is not None]
    if with_ttft:
        worst = max(with_ttft, key=lambda r: r["ttft"])
        lines.append("")
        lines.append(f"== slowest request: {worst['req']} "
                     f"(ttft {worst['ttft'] * 1e3:.3f} ms) ==")
        prev = None
        for e in worst["events"]:
            delta = (e["ts"] - prev) * 1e3 if prev is not None else 0.0
            prev = e["ts"]
            args = e.get("args") or {}
            arg_s = " ".join(f"{k}={v}" for k, v in args.items()
                             if k != "scope")
            dur_s = (f" dur={e['dur'] * 1e3:.3f}ms"
                     if e.get("dur") else "")
            lines.append(f"  +{delta:9.3f}ms  {e['name']:<14}{dur_s}"
                         f"  {arg_s}")

    # -- autoscale decisions -------------------------------------------------
    decisions = [e for e in events
                 if e["cat"] == "autoscale" and e["name"] == "decision"]
    if decisions:
        lines.append("")
        lines.append("== autoscale decisions ==")
        for e in decisions:
            args = e.get("args") or {}
            lines.append(f"  t={e['ts']:8.3f}s app={e.get('scope')} "
                         f"{args.get('action', '?'):<10} "
                         f"{args.get('reason', '')}")
    return "\n".join(lines)
