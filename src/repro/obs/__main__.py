"""``python -m repro.obs <trace-file>`` -- summarize an exported trace.

Accepts either export format (Chrome trace JSON from ``--trace`` /
``write_chrome_trace``, or JSONL from ``write_jsonl``) and prints the
per-request lifecycle table, p50/p95/p99 latency tables, and the
slowest-request critical path.  See docs/observability.md.
"""

from __future__ import annotations

import argparse

from .export import load_events
from .summary import summarize


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize a repro.obs trace file "
                    "(Chrome trace JSON or JSONL).")
    ap.add_argument("trace", help="trace file written by --trace or "
                                  "repro.obs.export")
    args = ap.parse_args(argv)
    events = load_events(args.trace)
    try:
        print(summarize(events))
    except BrokenPipeError:        # `... | head` closed the pipe: fine
        return 0
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
