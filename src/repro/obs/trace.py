"""Request-lifecycle tracing: a bounded ring buffer of typed events.

The serving and autoscale planes emit lifetime *aggregates*
(``EngineStats`` sums, ``POOL_COUNTERS``), which answer "how did the run
go" but never "why was THIS request slow".  The :class:`Tracer` is the
missing per-event substrate: every significant moment of a request's
life (submit -> admit -> prefix pin -> prefill chunks -> batched decode
ticks -> preempt/park/unpark -> finish/reject), every pool arbitration
(grant / denial / eviction / cache donation), every XLA compile
(``prefill_traces`` / ``decode_traces`` attribution), and every
autoscale decision WITH its explanation (which rule fired and the
windowed rates it saw) lands here as one tuple with a monotonic
``perf_counter`` timestamp.

Overhead discipline (zenlint ZL004 stays green on every instrumented
hot path):

* **off by default** -- the module global :data:`TRACER` is ``None``;
  every instrumentation site is ``t = trace.TRACER`` + ``if t is not
  None`` + one method call, so the disabled cost is one module
  attribute read and a ``None`` check (no string formatting, no dict
  building, no timestamps);
* **guard-and-append only when enabled** -- an event is one tuple
  appended to a ``deque(maxlen=capacity)``; no I/O, no formatting, no
  host syncs on device values (event args must already be host
  scalars);
* **bounded** -- the ring drops the OLDEST events when full and counts
  the drops (``tracer.dropped``), so a week-long serving process can
  leave tracing on.

Event model (Chrome ``trace_event``-shaped, see ``repro.obs.export``):

``(ts, dur, ph, cat, name, scope, args)`` where ``ph`` is ``"i"``
(instant) or ``"X"`` (complete span, ``dur`` seconds), ``cat`` is the
subsystem (``request`` / ``engine`` / ``pool`` / ``compile`` /
``autoscale`` / ``scheduler``), ``scope`` groups events onto one
timeline lane (a request id, an app name, or None for the engine-wide
lane), and ``args`` is a small dict of host scalars (or None).

Timebase: ``time.perf_counter()`` everywhere -- the same clock the
engine stamps ``Request.submitted_at`` with, so trace timestamps and
engine latencies compose exactly (see ``runtime.cluster`` train steps,
normalized in this PR).
"""

from __future__ import annotations

import collections
import time
from typing import Deque, Dict, List, Optional, Tuple

#: one trace event: (ts_s, dur_s, ph, cat, name, scope, args)
Event = Tuple[float, float, str, str, str, Optional[str], Optional[Dict]]

#: the process-wide tracer; None = tracing disabled (the default).
#: Instrumentation sites read this module attribute directly::
#:
#:     t = trace.TRACER
#:     if t is not None:
#:         t.instant("request", "submit", req.req_id)
TRACER: Optional["Tracer"] = None

DEFAULT_CAPACITY = 1 << 16


class Tracer:
    """Bounded ring buffer of typed trace events (monotonic timestamps)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self.events: Deque[Event] = collections.deque(maxlen=self.capacity)
        self.dropped = 0
        self.t0 = time.perf_counter()

    # -- emission (the hot-path API: guard-and-append only) ------------------
    def instant(self, cat: str, name: str, scope: Optional[str] = None,
                args: Optional[Dict] = None) -> None:
        """One zero-duration event at now."""
        ev = self.events
        if len(ev) == self.capacity:
            self.dropped += 1
        ev.append((time.perf_counter(), 0.0, "i", cat, name, scope, args))

    def span(self, cat: str, name: str, t_start: float, t_end: float,
             scope: Optional[str] = None,
             args: Optional[Dict] = None) -> None:
        """One complete span: the caller measured ``t_start``/``t_end``
        with ``perf_counter`` (no clock read here -- the span must not
        include the tracer's own bookkeeping)."""
        ev = self.events
        if len(ev) == self.capacity:
            self.dropped += 1
        ev.append((t_start, t_end - t_start, "X", cat, name, scope, args))

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    def snapshot(self) -> List[Event]:
        """A stable copy of the current ring (oldest first)."""
        return list(self.events)

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0

    def by_name(self, name: str, cat: Optional[str] = None) -> List[Event]:
        """Events with ``name`` (and ``cat`` when given), oldest first --
        the test/CLI convenience accessor, not a hot-path API."""
        return [e for e in self.events
                if e[4] == name and (cat is None or e[3] == cat)]

    def by_scope(self, scope: str) -> List[Event]:
        return [e for e in self.events if e[5] == scope]


def enable(capacity: int = DEFAULT_CAPACITY) -> Tracer:
    """Install (and return) a fresh process-wide tracer.  Idempotent in
    spirit: a second call replaces the ring (the old events are the
    caller's to keep via ``snapshot()`` first)."""
    global TRACER
    TRACER = Tracer(capacity)
    return TRACER


def disable() -> Optional[Tracer]:
    """Remove the process-wide tracer; returns it (with its events) so a
    caller can still export what was captured."""
    global TRACER
    t, TRACER = TRACER, None
    return t


def current() -> Optional[Tracer]:
    return TRACER
