"""Streaming ``/metrics`` endpoint: stdlib-only Prometheus exposition.

A :class:`MetricsServer` runs a daemonized ``ThreadingHTTPServer`` that
renders the process-global :class:`~repro.obs.metrics.MetricsRegistry`
(or an explicitly bound one) on every ``GET /metrics``.  Scraping is
read-only and lock-free on the serving path: the registry's counters
are plain dict updates, and ``render()`` snapshots whatever values the
scrape observes -- the standard Prometheus contract (each sample is
individually consistent, the set is not atomic).

Stdlib only (``http.server``): the container bakes no web framework,
and a pull-based text endpoint needs none.

Use::

    srv = serve_metrics(port=9108)      # 0 picks an ephemeral port
    print(srv.port)
    ...
    srv.stop()

or via ``launch/serve.py --metrics-port``.
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs import metrics as obs_metrics


class MetricsServer:
    """Background HTTP listener exposing Prometheus text metrics.

    ``registry=None`` (the default) re-reads the module-global
    ``repro.obs.metrics.METRICS`` on every request, so a server started
    before ``enable_metrics()`` begins serving real data the moment
    metrics are enabled (and 503s until then).
    """

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registry=None):
        self._registry = registry
        self._thread: Optional[threading.Thread] = None

        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):          # noqa: N802 (http.server API)
                if self.path.split("?", 1)[0] not in ("/", "/metrics"):
                    self.send_error(404, "try /metrics")
                    return
                reg = server._registry or obs_metrics.METRICS
                if reg is None:
                    body = b"metrics disabled (call enable_metrics())\n"
                    self.send_response(503)
                else:
                    body = reg.render().encode("utf-8")
                    self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # silent: scrapes are periodic
                pass

        self._httpd = ThreadingHTTPServer((host, int(port)), Handler)
        self._httpd.daemon_threads = True

    @property
    def port(self) -> int:
        """The bound port (useful with ``port=0``)."""
        return self._httpd.server_address[1]

    def start(self) -> "MetricsServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="repro-metrics",
                daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        """Shut the listener down and join its thread (idempotent)."""
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=5.0)
            self._thread = None
        self._httpd.server_close()


def serve_metrics(port: int = 0, host: str = "127.0.0.1",
                  registry=None) -> MetricsServer:
    """Start a :class:`MetricsServer` (returns it already listening)."""
    return MetricsServer(port=port, host=host, registry=registry).start()
