"""Fixed-bucket histograms + a Prometheus-style text exposition.

The autoscale plane consumes EWMA *rates* (``MetricsWindow``); what it
cannot answer is distributional: p95 TTFT, tail decode latency, how full
decode batches actually run.  :class:`Histogram` is the fixed-bucket
primitive (observe = one ``bisect`` + two adds -- cheap enough for the
per-tick serving path), and :class:`MetricsRegistry` is the process-wide
collection of counters / gauges / histograms with a ``render()`` that
emits the Prometheus text exposition format (the ``--metrics-dump``
output of ``launch/serve.py``).

Off by default, same discipline as ``repro.obs.trace``: the module
global :data:`METRICS` is ``None`` until :func:`enable_metrics`;
instrumentation sites guard on it (one attribute read + ``None`` check
when disabled).

Windowed semantics: histogram bucket counts are monotonic counters, so
they delta and merge exactly like the engine counters.
:func:`hist_delta` / :func:`hist_merge` operate on the plain-dict
snapshot form (``to_dict``), which is what ``serving_stats()`` carries
and ``autoscale.metrics.stats_delta`` windows -- counter resets (a
fresh engine reusing an app name) clamp to the current value instead of
going negative.
"""

from __future__ import annotations

from bisect import bisect_right
from typing import Dict, List, Optional, Sequence, Tuple

#: the process-wide registry; None = metrics disabled (the default)
METRICS: Optional["MetricsRegistry"] = None

#: default bucket bounds (upper edges, seconds) for latency histograms:
#: log-spaced from 50us to ~26s -- covers a CPU smoke decode step and a
#: pathological multi-second TTFT in the same 20 buckets
LATENCY_BOUNDS = tuple(50e-6 * 2 ** i for i in range(20))

#: batch occupancy / queue depth: linear small-integer buckets
OCCUPANCY_BOUNDS = tuple(float(i) for i in range(1, 33))


class Histogram:
    """Fixed upper-edge buckets, cumulative on render (Prometheus
    ``le`` semantics), plain per-bucket counts in memory."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Sequence[float] = LATENCY_BOUNDS):
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        assert list(self.bounds) == sorted(self.bounds), \
            "histogram bounds must be sorted"
        self.counts: List[int] = [0] * (len(self.bounds) + 1)  # +overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect_right(self.bounds, value)] += 1
        self.sum += value
        self.count += 1

    # -- analysis ------------------------------------------------------------
    def percentile(self, p: float) -> float:
        """Approximate p-quantile (0..100): the upper edge of the bucket
        containing the p-th observation (+inf -> the last finite edge).
        Exact enough for dashboards; the trace file has the raw points."""
        if self.count == 0:
            return 0.0
        rank = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return (self.bounds[i] if i < len(self.bounds)
                        else self.bounds[-1])
        return self.bounds[-1]

    @property
    def mean(self) -> float:
        return self.sum / max(self.count, 1)

    # -- snapshot / delta / merge (the windowed-stats integration) -----------
    def to_dict(self) -> Dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}

    @classmethod
    def from_dict(cls, d: Dict) -> "Histogram":
        h = cls(d["bounds"])
        h.counts = [int(c) for c in d["counts"]]
        h.sum = float(d["sum"])
        h.count = int(d["count"])
        return h

    def merge(self, other: "Histogram") -> "Histogram":
        """Element-wise sum (same bounds required): the cross-replica /
        cross-app aggregation the future router will lean on."""
        if self.bounds != other.bounds:
            raise ValueError("cannot merge histograms with different "
                             f"bounds: {self.bounds} vs {other.bounds}")
        out = Histogram(self.bounds)
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.sum = self.sum + other.sum
        out.count = self.count + other.count
        return out


def hist_delta(cur: Dict, since: Optional[Dict]) -> Dict:
    """Windowed view of a histogram snapshot dict: per-bucket counter
    deltas since ``since``.  A counter reset (since > cur anywhere, e.g.
    a fresh engine re-registered under an old app name) clamps to the
    CURRENT values -- a window must never report negative counts."""
    if since is None or since.get("bounds") != cur.get("bounds"):
        return {k: (list(v) if isinstance(v, list) else v)
                for k, v in cur.items()}
    counts = [c - s for c, s in zip(cur["counts"], since["counts"])]
    if any(c < 0 for c in counts):
        return {k: (list(v) if isinstance(v, list) else v)
                for k, v in cur.items()}
    return {"bounds": list(cur["bounds"]), "counts": counts,
            "sum": max(cur["sum"] - since["sum"], 0.0),
            "count": max(cur["count"] - since["count"], 0)}


def hist_merge(dicts: Sequence[Dict]) -> Dict:
    """Merge histogram snapshot dicts (same bounds) element-wise."""
    hs = [Histogram.from_dict(d) for d in dicts]
    out = hs[0]
    for h in hs[1:]:
        out = out.merge(h)
    return out.to_dict()


class MetricsRegistry:
    """Counters / gauges / histograms keyed ``(name, labels)``, with a
    Prometheus text exposition.  Labels are a sorted tuple of ``(k, v)``
    pairs (``app`` is the one the serving plane uses)."""

    def __init__(self):
        self.counters: Dict[Tuple, float] = {}
        self.gauges: Dict[Tuple, float] = {}
        self.histograms: Dict[Tuple, Histogram] = {}

    @staticmethod
    def _key(name: str, labels: Dict[str, str]) -> Tuple:
        return (name, tuple(sorted(labels.items())))

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        k = self._key(name, labels)
        self.counters[k] = self.counters.get(k, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self.gauges[self._key(name, labels)] = float(value)

    def histogram(self, name: str, bounds: Sequence[float] = LATENCY_BOUNDS,
                  **labels) -> Histogram:
        """Get-or-create: instrumentation can hold the returned object
        and call ``observe`` directly (no per-observation dict lookup)."""
        k = self._key(name, labels)
        h = self.histograms.get(k)
        if h is None:
            h = Histogram(bounds)
            self.histograms[k] = h
        return h

    def app_histograms(self, app: str) -> Dict[str, Dict]:
        """Snapshot dicts of every histogram labeled ``app=<app>`` --
        the ``hist`` sub-dict ``serving_stats()`` carries."""
        out = {}
        for (name, labels), h in self.histograms.items():
            if ("app", app) in labels:
                out[name] = h.to_dict()
        return out

    # -- exposition ----------------------------------------------------------
    @staticmethod
    def _label_str(labels: Tuple, extra: str = "") -> str:
        parts = [f'{k}="{v}"' for k, v in labels]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def render(self) -> str:
        """Prometheus text exposition of everything registered."""
        lines: List[str] = []
        for (name, labels), v in sorted(self.counters.items()):
            lines.append(f"# TYPE {name} counter")
            lines.append(f"{name}{self._label_str(labels)} {v:g}")
        for (name, labels), v in sorted(self.gauges.items()):
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name}{self._label_str(labels)} {v:g}")
        for (name, labels), h in sorted(self.histograms.items()):
            lines.append(f"# TYPE {name} histogram")
            cum = 0
            for edge, c in zip(h.bounds, h.counts):
                cum += c
                le = 'le="%g"' % edge
                lines.append(f"{name}_bucket"
                             f"{self._label_str(labels, le)} {cum}")
            cum += h.counts[-1]
            lines.append(f"{name}_bucket"
                         + self._label_str(labels, 'le="+Inf"')
                         + f" {cum}")
            lines.append(f"{name}_sum{self._label_str(labels)} {h.sum:g}")
            lines.append(f"{name}_count{self._label_str(labels)} {h.count}")
        return "\n".join(lines) + "\n"


def enable_metrics() -> MetricsRegistry:
    """Install (and return) a fresh process-wide registry."""
    global METRICS
    METRICS = MetricsRegistry()
    return METRICS


def disable_metrics() -> Optional[MetricsRegistry]:
    global METRICS
    m, METRICS = METRICS, None
    return m


def current_metrics() -> Optional[MetricsRegistry]:
    return METRICS
