"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON and JSONL.

Export happens *offline* (end of run, or on demand) -- never on the hot
path.  The in-memory event tuples (see ``repro.obs.trace``) map onto the
Chrome trace-event format:

* ``ph: "i"`` instants and ``ph: "X"`` complete spans,
* ``ts``/``dur`` in microseconds relative to the tracer's ``t0`` (so a
  trace always starts near 0),
* ``pid`` = the subsystem category (one process row per cat in the
  Perfetto UI), ``tid`` = the event's scope (one thread lane per
  request id / app name), with ``M``-phase metadata events naming the
  rows so the UI shows ``request`` / ``pool`` / ``autoscale`` groups
  with per-request lanes inside.

Load the JSON into https://ui.perfetto.dev or ``chrome://tracing``; the
JSONL form is one event-object per line for ad-hoc ``jq``/pandas work
and is what ``python -m repro.obs`` also accepts.
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

from .trace import Event, Tracer

#: stable pid assignment per category so lanes group deterministically
CAT_PIDS = {"request": 1, "engine": 2, "pool": 3, "compile": 4,
            "autoscale": 5, "scheduler": 6}
_OTHER_PID = 99


def _tid_map(events: Iterable[Event]) -> Dict[tuple, int]:
    """Assign a stable tid per (pid, scope), in first-seen order; the
    scope-less engine-wide lane is tid 0."""
    tids: Dict[tuple, int] = {}
    for ev in events:
        pid = CAT_PIDS.get(ev[3], _OTHER_PID)
        key = (pid, ev[5] or "")
        if key not in tids:
            tids[key] = 0 if ev[5] is None else len(tids) + 1
    return tids


def to_chrome_events(tracer: Tracer) -> List[Dict]:
    """The tracer's ring as a list of Chrome trace-event dicts
    (metadata rows first, then events oldest-first)."""
    events = tracer.snapshot()
    tids = _tid_map(events)
    t0 = tracer.t0
    out: List[Dict] = []
    # metadata: name the process rows and thread lanes
    for cat, pid in sorted(CAT_PIDS.items(), key=lambda kv: kv[1]):
        out.append({"ph": "M", "pid": pid, "tid": 0,
                    "name": "process_name", "args": {"name": cat}})
    for (pid, scope), tid in sorted(tids.items(), key=lambda kv: kv[1]):
        if scope:
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_name", "args": {"name": scope}})
    for ts, dur, ph, cat, name, scope, args in events:
        pid = CAT_PIDS.get(cat, _OTHER_PID)
        rec = {"ph": ph, "pid": pid, "tid": tids[(pid, scope or "")],
               "ts": (ts - t0) * 1e6, "cat": cat, "name": name}
        if ph == "X":
            rec["dur"] = dur * 1e6
        if ph == "i":
            rec["s"] = "t"  # thread-scoped instant marker
        if args:
            rec["args"] = dict(args)
        elif scope:
            rec["args"] = {}
        if scope:
            rec.setdefault("args", {})["scope"] = scope
        out.append(rec)
    return out


def write_chrome_trace(tracer: Tracer, path: str,
                       extra_meta: Optional[Dict] = None) -> int:
    """Write the full ``{"traceEvents": [...]}`` JSON object form (the
    one Perfetto/chrome://tracing load directly).  Returns the number of
    trace events written (metadata rows excluded)."""
    events = to_chrome_events(tracer)
    n = sum(1 for e in events if e["ph"] != "M")
    doc = {"traceEvents": events,
           "displayTimeUnit": "ms",
           "otherData": {"dropped_events": tracer.dropped,
                         **(extra_meta or {})}}
    with open(path, "w") as f:
        json.dump(doc, f)
    return n


def write_jsonl(tracer: Tracer, path: str) -> int:
    """One raw event object per line (not Chrome-shaped: keeps the
    native ts/dur seconds and scope field) for jq/pandas pipelines."""
    events = tracer.snapshot()
    t0 = tracer.t0
    with open(path, "w") as f:
        for ts, dur, ph, cat, name, scope, args in events:
            rec = {"ts": ts - t0, "dur": dur, "ph": ph, "cat": cat,
                   "name": name}
            if scope is not None:
                rec["scope"] = scope
            if args:
                rec["args"] = dict(args)
            f.write(json.dumps(rec) + "\n")
    return len(events)


def load_events(path: str) -> List[Dict]:
    """Load either export format back into a flat list of event dicts
    with keys ts (seconds), dur (seconds), ph, cat, name, scope, args.
    Chrome metadata rows are dropped; Chrome us units are converted."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None                       # JSONL: one object per line
    if doc is not None:
        raw = doc["traceEvents"] if isinstance(doc, dict) else doc
        out = []
        for e in raw:
            if e.get("ph") == "M":
                continue
            args = dict(e.get("args") or {})
            scope = args.pop("scope", None)
            out.append({"ts": e.get("ts", 0.0) / 1e6,
                        "dur": e.get("dur", 0.0) / 1e6,
                        "ph": e["ph"], "cat": e.get("cat", ""),
                        "name": e["name"], "scope": scope,
                        "args": args})
        return out
    out = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        e = json.loads(line)
        e.setdefault("dur", 0.0)
        e.setdefault("scope", None)
        e.setdefault("args", {})
        out.append(e)
    return out
