"""zensan: shadow-ledger sanitizer for the paged KV data plane.

zenlint (``repro.analysis.engine``) proves accounting invariants
*syntactically*, one function at a time.  The invariants that actually
protect tenants from each other, though, are cross-module *runtime*
properties of the arbitration state machine spanning
``SharedPagePool`` <-> ``PoolView`` <-> ``KVArrayStore`` <->
``PrefixCache`` <-> parking: conservation (every physical page is free,
view-granted, or cache-resident -- exactly one of the three), receipt
balance (park releases exactly what unpark restores), refcount sanity
(never negative, never stranded at eviction), and id-space isolation
(view-local ids never reach a decode table -- the runtime twin of
zenlint's ZL001).

This module mirrors every mutation of that state machine into an
independent **shadow ledger** and re-derives the invariants after each
step.  The design mirrors ``repro.obs.trace``:

* ``SAN`` is a module global, ``None`` by default.  Every instrumented
  site guards with ``s = zensan.SAN`` / ``if s is not None`` -- when
  disabled the entire plane costs one attribute load + one is-check per
  hook site, and attaches nothing to pool objects.
* ``REPRO_ZENSAN=1`` in the environment enables it at import time
  (strict mode: the first violation raises ``ZensanViolation``);
  ``REPRO_ZENSAN_REPORT=<path>`` additionally appends every violation
  to a report file (the CI artifact).

Shadow state lives ON the objects it mirrors (``pool._zs_ledger``,
``cache._zs_refs``, ``store._zs_local``) so its lifetime matches theirs
-- a global table keyed by ``id()`` would silently corrupt when ids are
reused after GC.  Ledgers snapshot lazily from the real structures on
first hook (and re-snapshot when ``enable()`` bumps the generation), so
the sanitizer can attach to a mid-flight pool and only validates
mutations it actually observed.

Page-state machine (per physical page of one root pool)::

    FREE --take--> STAGED --grant--> VIEW(app) --release--> STAGED
    STAGED --give--> FREE            VIEW --cache_donated--> CACHE(c)
    CACHE --give (cache free_fn on evict)--> FREE

``STAGED`` is the window between the shared pool popping pages and the
view remapping them (or the reverse); it is what lets both layers hook
independently without double-counting, and ``check()`` asserts it is
empty at every quiescent point (engine step end, park/unpark end).

``explore()`` is a bounded model checker over the same hooks: it
replays every depth-N interleaving of the arbitration ops
{grant, preempt, evict, park, unpark, prefix pin, donate} against a
small two-tenant model pool and checks the full ledger after every
single op.  See docs/analysis.md for the invariant catalogue.
"""

from __future__ import annotations

import os
import traceback
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["SAN", "Sanitizer", "Violation", "ZensanViolation",
           "enable", "disable", "explore", "ExploreResult"]

#: page-state constants (owner-table values; FREE is absence)
_STAGED = ("staged",)

#: bumped by enable()/disable(): shadow state from an older generation
#: is stale (mutations happened unobserved) and is re-snapshotted
_GEN = 0


@dataclass
class Violation:
    """One invariant breach: the rule name (tests match on these), a
    human message, the offending *product* call site, and -- for
    conservation sweeps -- the ledger-vs-real diff."""

    rule: str
    message: str
    site: str
    diff: str = ""

    def render(self) -> str:
        out = f"zensan[{self.rule}] {self.message} @ {self.site}"
        if self.diff:
            out += f"\n  ledger diff: {self.diff}"
        return out


class ZensanViolation(AssertionError):
    """Raised in strict mode on the first ledger violation."""


def _site() -> str:
    """The innermost stack frame outside this module: the product code
    whose mutation (or whose quiescent point) tripped the invariant."""
    here = os.path.basename(__file__)
    for fr in reversed(traceback.extract_stack()):
        base = os.path.basename(fr.filename)
        if base != here:
            return f"{base}:{fr.lineno} in {fr.name}"
    return "<unknown>"


def _root(pool):
    """The object owning the physical page space: a PoolView's shared
    pool, else the (private) pool itself."""
    return getattr(pool, "shared", None) or pool


def _fmt(owner) -> str:
    if owner is None:
        return "FREE"
    if owner is _STAGED or owner == _STAGED:
        return "STAGED"
    kind, who = owner
    return f"{kind.upper()}({who!r})" if kind == "view" else f"CACHE(#{who})"


def _iter_caches(root):
    """Every prefix cache whose pages live in ``root``'s page space:
    the pod registry, a private pool's own cache, and any view-private
    cache (un-aliased tenant on a shared pool)."""
    seen = set()
    pcs = getattr(root, "prefix_caches", None)
    if pcs:
        for c in pcs.values():
            if id(c) not in seen:
                seen.add(id(c))
                yield c
    # NB: on SharedPagePool ``prefix_cache`` is the registry *accessor*
    # (a method); only a PagePool/PoolView carries a cache object there
    c = getattr(root, "prefix_cache", None)
    if c is not None and hasattr(c, "nodes") and id(c) not in seen:
        seen.add(id(c))
        yield c
    for v in getattr(root, "views", {}).values():
        c = getattr(v, "prefix_cache", None)
        if c is not None and hasattr(c, "nodes") and id(c) not in seen:
            seen.add(id(c))
            yield c


class Ledger:
    """Shadow owner table of ONE root pool's physical page space.

    ``owner`` maps page id -> ``("view", app)`` / ``("cache", cache-id)``
    / ``STAGED``; absence means FREE.  ``receipts`` holds outstanding
    park receipts keyed ``(app, req_id)`` -> ``(n_global, n_local)``.
    Snapshotted from the real structures at construction, maintained by
    the Sanitizer hooks afterwards."""

    __slots__ = ("gen", "total", "owner", "receipts")

    def __init__(self, root):
        self.gen = _GEN
        self.total = int(root.num_pages)
        self.owner: Dict[int, Tuple] = {}
        self.receipts: Dict[Tuple[str, str], Tuple[int, int]] = {}
        for cache in _iter_caches(root):
            for n in cache.nodes:
                self.owner[n.page] = ("cache", id(cache))
        views = getattr(root, "views", None)
        if views is not None:
            for app, v in views.items():
                for pid in v._remap.values():
                    self.owner[pid] = ("view", app)
        else:
            free = set(root.free)
            for pid in range(self.total):
                if pid not in free and pid not in self.owner:
                    self.owner[pid] = ("view", root.app)

    def free_set(self) -> set:
        return {p for p in range(self.total) if p not in self.owner}

    def owned_by(self, owner: Tuple) -> set:
        return {p for p, o in self.owner.items() if o == owner}


class _LocalSpace:
    """Shadow owner table of one local (sliding-window ring) page-id
    space.  The space's host is whoever owns the physical free list: a
    ``KVArrayStore`` (aliased tenants share it), a ``PoolView`` (private
    per-view space), or a private ``PagePool``.  ``flist`` anchors the
    exact list object -- ``set_groups`` replacing it redefines the id
    space, which invalidates this snapshot."""

    __slots__ = ("gen", "flist", "owner")

    def __init__(self, host, flist, root):
        self.gen = _GEN
        self.flist = flist
        self.owner: Dict[int, str] = {}
        if hasattr(host, "users"):                 # KVArrayStore
            for v in getattr(root, "views", {}).values():
                if getattr(v, "kv_store", None) is host:
                    for p in v._remap_local.values():
                        self.owner[p] = v.app
        elif hasattr(host, "_remap_local"):        # PoolView private space
            for p in host._remap_local.values():
                self.owner[p] = host.app
        else:                                      # private PagePool
            free = set(flist)
            for p in range(host._local_space()):
                if p not in free:
                    self.owner[p] = host.app


def _local_host(pool):
    st = getattr(pool, "kv_store", None)
    if st is not None and getattr(st, "free_local", None) is not None:
        return st
    return pool


class Sanitizer:
    """The hook sink.  Instrumented sites call one method per mutation;
    ``check()`` re-derives every invariant against the real structures.
    ``strict`` raises on the first violation (the CI/test mode);
    non-strict accumulates (the explorer mode, which wants the full
    violation set across an interleaving sweep)."""

    def __init__(self, strict: bool = True,
                 report_path: Optional[str] = None):
        self.strict = strict
        self.report_path = report_path
        self.violations: List[Violation] = []
        self.events = 0          # hook invocations observed (bench/meta)

    # -- plumbing ------------------------------------------------------------
    def _viol(self, rule: str, message: str, diff: str = "") -> None:
        v = Violation(rule, message, _site(), diff)
        self.violations.append(v)
        if self.report_path:
            try:
                d = os.path.dirname(self.report_path)
                if d:
                    os.makedirs(d, exist_ok=True)
                with open(self.report_path, "a") as f:
                    f.write(v.render() + "\n")
            except OSError:
                pass
        if self.strict:
            raise ZensanViolation(v.render())

    def _ledger(self, root) -> Tuple[Ledger, bool]:
        """-> (ledger, freshly-snapshotted).  A fresh snapshot reads the
        REAL structures, which mid-operation already reflect the mutation
        the triggering hook describes -- that hook must then coerce the
        state its composite's later hooks expect WITHOUT running its
        checks (there is no before-state to check against)."""
        led = getattr(root, "_zs_ledger", None)
        if led is None or led.gen != _GEN:
            led = Ledger(root)
            root._zs_ledger = led
            return led, True
        return led, False

    def _space(self, pool, root) -> Tuple[Optional[_LocalSpace], bool]:
        host = _local_host(pool)
        flist = getattr(host, "free_local", None)
        if flist is None:
            return None, False
        sp = getattr(host, "_zs_local", None)
        if sp is None or sp.gen != _GEN or sp.flist is not flist:
            sp = _LocalSpace(host, flist, root)
            host._zs_local = sp
            return sp, True
        return sp, False

    def _refs(self, cache) -> Tuple[Dict[int, int], bool]:
        """-> (shadow refcounts, freshly-snapshotted).  A fresh snapshot
        reads the REAL post-mutation refs, so the hook that triggered it
        must not re-apply its delta on top."""
        refs = getattr(cache, "_zs_refs", None)
        if refs is None or getattr(cache, "_zs_gen", None) != _GEN:
            refs = {id(n): n.refs for n in cache.nodes}
            cache._zs_refs = refs
            cache._zs_gen = _GEN
            return refs, True
        return refs, False

    # -- global page-space hooks ---------------------------------------------
    def take(self, pool, pages: List[int]) -> None:
        """Pages popped off the root free list (FREE -> STAGED)."""
        self.events += 1
        led, fresh = self._ledger(_root(pool))
        for p in pages:
            if not fresh:
                cur = led.owner.get(p)
                if cur is not None:
                    self._viol("double-grant",
                               f"page {p} popped from the free list while "
                               f"the ledger holds it as {_fmt(cur)}")
            led.owner[p] = _STAGED

    def give(self, pool, pages: List[int]) -> None:
        """Pages pushed back on the root free list (STAGED/CACHE ->
        FREE).  A page already free is a double-free; a page still
        granted to a view is freed out from under its owner."""
        self.events += 1
        led, fresh = self._ledger(_root(pool))
        if fresh:
            # hook fires just before the real free-list extend: FREE is
            # where these pages are headed, and absence IS free
            for p in pages:
                led.owner.pop(p, None)
            return
        for p in pages:
            cur = led.owner.get(p)
            if cur is None:
                self._viol("double-free",
                           f"page {p} returned to the free list twice")
            elif cur[0] == "view":
                self._viol("foreign-free",
                           f"page {p} freed while still granted to view "
                           f"{cur[1]!r} (no release observed)")
            else:
                del led.owner[p]

    def grant(self, pool, vids: List[int], phys: List[int]) -> None:
        """Physical pages bound to a view's remap (STAGED -> VIEW) --
        the one point where quota <= cap is enforceable."""
        self.events += 1
        app = getattr(pool, "app", "?")
        led, fresh = self._ledger(_root(pool))
        for p in phys:
            if not fresh:
                cur = led.owner.get(p)
                if cur != _STAGED:
                    self._viol("double-grant",
                               f"page {p} granted to view {app!r} while "
                               f"the ledger holds it as {_fmt(cur)}")
            led.owner[p] = ("view", app)
        quota = getattr(pool, "quota", None)
        used = getattr(pool, "used", None)
        if quota is not None and used is not None and used > quota:
            self._viol("quota-overdraft",
                       f"view {app!r} holds used={used} > quota={quota} "
                       f"after a grant of {len(phys)} page(s)")

    def release(self, pool, vids: List[int], phys: List[int]) -> None:
        """View remap entries dropped (VIEW -> STAGED); ``give``
        completes the round trip."""
        self.events += 1
        app = getattr(pool, "app", "?")
        led, fresh = self._ledger(_root(pool))
        for p in phys:
            if not fresh:
                cur = led.owner.get(p)
                if cur != ("view", app):
                    self._viol("foreign-free",
                               f"view {app!r} released page {p} the "
                               f"ledger holds as {_fmt(cur)}")
            led.owner[p] = _STAGED

    def cache_donated(self, pool, phys: List[int], cache) -> None:
        """Pages moved out of a view's accounting into prefix-cache
        ownership (VIEW -> CACHE): off the quota, NOT on the free list."""
        self.events += 1
        app = getattr(pool, "app", "?")
        led, fresh = self._ledger(_root(pool))
        ckey = id(cache) if cache is not None else 0
        for p in phys:
            if not fresh:
                cur = led.owner.get(p)
                if cur != ("view", app):
                    self._viol("foreign-free",
                               f"view {app!r} donated page {p} the ledger "
                               f"holds as {_fmt(cur)}")
            led.owner[p] = ("cache", ckey)

    # -- local (ring) page-space hooks ---------------------------------------
    def grant_local(self, pool, phys: List[int]) -> None:
        self.events += 1
        app = getattr(pool, "app", "?")
        sp, fresh = self._space(pool, _root(pool))
        if sp is None:
            return
        for p in phys:
            if not fresh:
                cur = sp.owner.get(p)
                if cur is not None:
                    self._viol("double-grant",
                               f"local page {p} granted to {app!r} while "
                               f"owned by {cur!r}")
            sp.owner[p] = app
        quota = getattr(pool, "quota", None)
        used = getattr(pool, "used_local", None)
        if quota is not None and used is not None and used > quota:
            self._viol("quota-overdraft",
                       f"view {app!r} holds used_local={used} > "
                       f"quota={quota} after a local grant of {len(phys)}")

    def release_local(self, pool, phys: List[int]) -> None:
        self.events += 1
        app = getattr(pool, "app", "?")
        sp, fresh = self._space(pool, _root(pool))
        if sp is None:
            return
        for p in phys:
            cur = sp.owner.pop(p, None)
            if fresh:
                continue          # no before-state to hold anyone to
            if cur is None:
                self._viol("double-free",
                           f"local page {p} returned to the free list "
                           "twice")
            elif cur != app:
                self._viol("foreign-free",
                           f"view {app!r} released local page {p} owned "
                           f"by {cur!r}")

    # -- prefix-cache refcount hooks -----------------------------------------
    def pinned(self, cache, nodes) -> None:
        self.events += 1
        refs, fresh = self._refs(cache)
        if fresh:
            return                # snapshot already holds the new pins
        for n in nodes:
            if id(n) in refs:
                refs[id(n)] += 1
            else:                 # un-hooked creation: adopt post-state
                refs[id(n)] = n.refs

    def unpinned(self, cache, nodes) -> None:
        self.events += 1
        refs, fresh = self._refs(cache)
        if fresh:
            return
        for n in nodes:
            if id(n) not in refs:
                refs[id(n)] = n.refs
                continue
            refs[id(n)] -= 1
            if refs[id(n)] < 0:
                self._viol("refcount-negative",
                           f"cache {cache.key!r}: unpin drove node page "
                           f"{n.page} below zero pins")
                refs[id(n)] = n.refs
    def inserted(self, cache, created) -> None:
        """Freshly adopted nodes come back pinned for the donor."""
        self.events += 1
        refs, fresh = self._refs(cache)
        if fresh:
            return
        for n in created:
            if id(n) in refs:
                refs[id(n)] += 1
            else:
                refs[id(n)] = n.refs

    def evicted(self, cache, node) -> None:
        """A node leaving the trie must carry zero pins; its page goes
        back through the cache's free_fn (CACHE -> FREE via ``give``)."""
        self.events += 1
        refs, _ = self._refs(cache)
        sh = refs.pop(id(node), node.refs)
        if sh != 0 or node.refs != 0:
            self._viol("refcount-stranded",
                       f"cache {cache.key!r}: evicted node page "
                       f"{node.page} with shadow refs={sh} "
                       f"(real {node.refs}) -- pinned pages must never "
                       "be evicted")

    # -- park / unpark receipts ----------------------------------------------
    def parked(self, pool, req_id: str, n: int, n_local: int) -> None:
        """reclaim(): the receipt unpark must balance, page-for-page."""
        self.events += 1
        app = getattr(pool, "app", "?")
        led, _ = self._ledger(_root(pool))
        led.receipts[(app, req_id)] = (n, n_local)

    def regranted(self, pool, req_id: str, n: int, n_local: int) -> None:
        self.events += 1
        app = getattr(pool, "app", "?")
        led, _ = self._ledger(_root(pool))
        rec = led.receipts.pop((app, req_id), None)
        if rec is not None and rec != (n, n_local):
            self._viol("park-mismatch",
                       f"request {req_id!r} ({app!r}) parked "
                       f"{rec[0]}+{rec[1]} pages but was regranted "
                       f"{n}+{n_local}")

    def park_cancel(self, pool, req_id: str) -> None:
        """The request falls back to the at-least-once requeue path (no
        regrant will come): the receipt is resolved, not stranded."""
        self.events += 1
        app = getattr(pool, "app", "?")
        self._ledger(_root(pool))[0].receipts.pop((app, req_id), None)

    def unpark_done(self, pool, app: str) -> None:
        """End of unpark: every one of the app's park receipts must have
        been regranted or explicitly cancelled."""
        self.events += 1
        led, _ = self._ledger(_root(pool))
        stale = [k for k in led.receipts if k[0] == app]
        for key in stale:
            n, n_local = led.receipts.pop(key)
            self._viol("stranded-park-receipt",
                       f"request {key[1]!r} ({app!r}) parked "
                       f"{n}+{n_local} pages but unpark neither "
                       "regranted nor requeued it")

    # -- runtime id-escape (decode tables; zenlint ZL001's twin) -------------
    def table(self, pool, g_rows, l_rows) -> None:
        """Every physical id entering a decode page table must be a page
        this view owns or a (read-only) cache page -- anything else is a
        view-local id that escaped translation, or another tenant's
        page."""
        self.events += 1
        if pool is None:
            return
        app = getattr(pool, "app", "?")
        led, _ = self._ledger(_root(pool))
        for row in g_rows:
            for p in row:
                o = led.owner.get(p)
                if o is None or (o[0] == "view" and o[1] != app) \
                        or o is _STAGED or o == _STAGED:
                    self._viol("id-escape",
                               f"decode table for {app!r} references "
                               f"physical page {p} held as {_fmt(o)}")
        sp, _ = self._space(pool, _root(pool))
        if sp is None:
            return
        for row in l_rows:
            for p in row:
                if sp.owner.get(p) != app:
                    self._viol("id-escape",
                               f"decode ring table for {app!r} references "
                               f"local page {p} held by "
                               f"{sp.owner.get(p)!r}")

    # -- dense backend slot table --------------------------------------------
    def dense_state(self, runner, running) -> None:
        """DenseRunner bookkeeping: every running request has a slot and
        a token tail, and no two share a slot."""
        self.events += 1
        seen: Dict[int, str] = {}
        for r in running:
            ent = runner.slots.get(r.req_id)
            if ent is None:
                self._viol("dense-slot",
                           f"running request {r.req_id!r} has no dense "
                           "slot")
                continue
            slot = ent[0] if isinstance(ent, tuple) else ent
            if slot in seen:
                self._viol("dense-slot",
                           f"slot {slot} assigned to both {seen[slot]!r} "
                           f"and {r.req_id!r}")
            seen[slot] = r.req_id
            if r.req_id not in runner.generated:
                self._viol("dense-slot",
                           f"running request {r.req_id!r} has no "
                           "generated-token tail")

    # -- teardown ------------------------------------------------------------
    def view_closed(self, view) -> None:
        """A view detaching from the pod must hold nothing; its park
        receipts (an app released while parked) are torn down with it."""
        self.events += 1
        app = getattr(view, "app", "?")
        led, _ = self._ledger(_root(view))
        owned = led.owned_by(("view", app))
        if owned:
            self._viol("view-leak",
                       f"view {app!r} closed while still holding "
                       f"{len(owned)} page(s): {sorted(owned)}")
            for p in owned:
                del led.owner[p]
        for key in [k for k in led.receipts if k[0] == app]:
            del led.receipts[key]

    # -- the full sweep ------------------------------------------------------
    def check(self, pool) -> None:
        """Re-derive every invariant at a quiescent point (engine step
        end, park/unpark end, after each explorer op): the ledger and
        the real structures must tell the same story."""
        self.events += 1
        root = _root(pool)
        led, _ = self._ledger(root)
        diffs: List[str] = []

        real_free = list(root.free)
        if len(set(real_free)) != len(real_free):
            dup = sorted(p for p in set(real_free)
                         if real_free.count(p) > 1)
            diffs.append(f"free list holds duplicates: {dup}")
        led_free = led.free_set()
        if set(real_free) != led_free:
            missing = sorted(led_free - set(real_free))
            extra = sorted(set(real_free) - led_free)
            diffs.append(f"free-list mismatch: ledger-free-but-real-held "
                         f"{missing}, real-free-but-ledger-held {extra}")
        staged = sorted(led.owned_by(_STAGED))
        if staged:
            diffs.append(f"pages stuck in STAGED at a quiescent point: "
                         f"{staged}")

        views = getattr(root, "views", None)
        if views is not None:
            for app, v in views.items():
                owned = led.owned_by(("view", app))
                remap = set(v._remap.values())
                if remap != owned:
                    diffs.append(
                        f"view {app!r}: remap pages {sorted(remap)} != "
                        f"ledger grant {sorted(owned)}")
                if v.used != len(v._remap):
                    diffs.append(f"view {app!r}: used={v.used} != "
                                 f"|remap|={len(v._remap)}")

        for cache in _iter_caches(root):
            refs, _ = self._refs(cache)
            live = set()
            pages = set()
            for n in cache.nodes:
                live.add(id(n))
                pages.add(n.page)
                sh = refs.get(id(n))
                if sh is None:
                    refs[id(n)] = n.refs
                elif sh != n.refs:
                    self._viol(
                        "refcount-leak",
                        f"cache {cache.key!r}: node page {n.page} has "
                        f"real refs={n.refs} but shadow refs={sh} -- a "
                        "pin/unpin bypassed the hooks or leaked")
                    refs[id(n)] = n.refs
            for k in [k for k in refs if k not in live]:
                del refs[k]
            owned = led.owned_by(("cache", id(cache)))
            if pages != owned:
                diffs.append(
                    f"cache {cache.key!r}: trie pages {sorted(pages)} != "
                    f"ledger cache-owned {sorted(owned)}")

        if views is not None:
            for key, st in root.kv_stores.items():
                for u in st.users:
                    v = views.get(u)
                    if v is None:
                        self._viol("store-users",
                                   f"KV store {key!r} lists user {u!r} "
                                   "but the pod has no such view")
                    elif getattr(v, "kv_store", None) is not st:
                        self._viol("store-users",
                                   f"KV store {key!r} lists user {u!r} "
                                   "whose view aliases a different store")
            for v in views.values():
                st = getattr(v, "kv_store", None)
                if st is not None and v.app not in st.users:
                    self._viol("store-users",
                               f"view {v.app!r} aliases store "
                               f"{st.key!r} but is missing from "
                               "store.users")

        self._check_local(root, views, diffs)

        if diffs:
            self._viol("conservation",
                       f"ledger/reality divergence on pool of "
                       f"{led.total} pages", diff="; ".join(diffs))

    def _check_local(self, root, views, diffs: List[str]) -> None:
        hosts = []
        if views is not None:
            for st in root.kv_stores.values():
                if getattr(st, "free_local", None) is not None:
                    hosts.append((st, [v for v in views.values()
                                       if getattr(v, "kv_store", None)
                                       is st]))
            for v in views.values():
                if (v.free_local is not None
                        and _local_host(v) is v):
                    hosts.append((v, [v]))
        elif getattr(root, "free_local", None) is not None:
            hosts.append((root, []))
        for host, vs in hosts:
            flist = host.free_local
            sp = getattr(host, "_zs_local", None)
            if sp is None or sp.gen != _GEN or sp.flist is not flist:
                continue          # never hooked this space: nothing owed
            if len(set(flist)) != len(flist):
                diffs.append("local free list holds duplicates")
            overlap = set(flist) & set(sp.owner)
            if overlap:
                diffs.append(f"local pages both free and granted: "
                             f"{sorted(overlap)}")
            for v in vs:
                mine = {p for p, a in sp.owner.items() if a == v.app}
                remap = set(v._remap_local.values())
                if remap != mine:
                    diffs.append(
                        f"view {v.app!r}: local remap {sorted(remap)} != "
                        f"ledger {sorted(mine)}")
                if v.used_local != len(v._remap_local):
                    diffs.append(
                        f"view {v.app!r}: used_local={v.used_local} != "
                        f"|remap_local|={len(v._remap_local)}")


#: THE sanitizer.  None (the default) means every hook site is a single
#: attribute load + is-check; enable() swaps in a live instance.
SAN: Optional[Sanitizer] = None


def enable(strict: bool = True,
           report_path: Optional[str] = None) -> Sanitizer:
    """Install a fresh sanitizer.  Bumps the shadow generation so every
    ledger re-snapshots from the real structures on next contact --
    mutations made while disabled were unobserved and must not count."""
    global SAN, _GEN
    _GEN += 1
    SAN = Sanitizer(strict=strict, report_path=report_path)
    return SAN


def disable() -> None:
    global SAN, _GEN
    _GEN += 1
    SAN = None


def _install(san: Optional[Sanitizer]) -> None:
    """Restore a previous sanitizer (the explorer's save/restore)."""
    global SAN, _GEN
    _GEN += 1
    SAN = san


# -- bounded schedule explorer ----------------------------------------------

#: the arbitration op alphabet: every depth-N product over these is one
#: schedule.  Two tenants ("a": two-page prompts, "b": one-page prompts
#: sharing a's leading page) over one 12-page pod pool + one shared
#: prefix cache covers grant/preempt/evict/park/unpark/pin/donate
#: interleavings including cross-tenant prefix reuse and COW pins.
EXPLORE_OPS = ("grant_a", "grant_b", "preempt_a", "park_a",
               "unpark_a", "evict", "pin_b", "donate_a")


@dataclass
class ExploreResult:
    depth: int
    sequences: int
    ops_applied: int
    violations: List[Violation] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations


def _model_state(pool_pages: int):
    from repro.serving.kv_cache import PAGE_SIZE
    from repro.serving.prefix_cache import PrefixCache
    from repro.serving.tenancy import SharedPagePool

    shared = SharedPagePool(pool_pages)
    cache = shared.prefix_cache(
        ("zensan-model",),
        lambda: PrefixCache(("zensan-model",), shared._give))
    views = {}
    for app in ("a", "b"):
        v = shared.view(app, quota="fair", policy="fixed",
                        fixed_init_pages=1, fixed_step_pages=1)
        v.prefix_cache = cache
        cache.users.add(app)
        views[app] = v
    return {
        "shared": shared, "cache": cache, "views": views,
        "prompts": {"a": tuple(range(2 * PAGE_SIZE)),
                    "b": tuple(range(PAGE_SIZE))},
        "running": {"a": [], "b": []},
        "parked": {"a": [], "b": []},
        "pins": [], "n": 0, "unpins": 0,
    }


def _op_grant(st, app) -> None:
    v = st["views"][app]
    if v.parked:
        return
    st["n"] += 1
    toks = st["prompts"][app]
    from repro.serving.kv_cache import Request
    r = Request(f"{app}{st['n']}", len(toks),
                max_new_tokens=8, prompt_tokens=toks)
    m = st["cache"].pin(toks, max_len=len(toks) - 1)
    r.prefix_nodes = m.nodes
    r.shared_pages = list(m.phys_pages)
    r.cached_len = m.cached_len
    r.cow_src_page = m.cow_src
    if v.try_admit(r):
        st["running"][app].append(r)
    else:
        v.prefix_detach(r)


def _op_preempt(st, app) -> None:
    run = st["running"][app]
    if run:
        st["views"][app].release(run.pop())


def _op_park(st, app) -> None:
    v = st["views"][app]
    if v.parked:
        return
    st["parked"][app] = [(r, v.reclaim(r)) for r in st["running"][app]]
    st["running"][app] = []
    v.parked = True


def _op_unpark(st, app) -> None:
    """The explorer plays the parking controller: re-pin, regrant --
    and resolve (cancel) the receipt of any request that falls back to
    the recompute path, exactly as autoscale.parking does."""
    v = st["views"][app]
    if not v.parked:
        return
    v.parked = False
    s = SAN
    cache = st["cache"]
    for r, (g, l) in st["parked"][app]:
        if r.parked_shared:
            m = cache.pin(r.prompt_tokens, max_full=r.parked_shared)
            if len(m.phys_pages) < r.parked_shared:
                # evicted while parked: recompute from scratch
                st["unpins"] += cache.unpin(m.nodes)
                r.prefix_nodes, r.shared_pages = None, []
                r.cached_len, r.cow_src_page, r.parked_shared = 0, None, 0
                if s is not None:
                    s.park_cancel(v, r.req_id)
                continue
            r.prefix_nodes = m.nodes
            r.shared_pages = list(m.phys_pages)
            r.parked_shared = 0
        if v.regrant(r, len(g), len(l)):
            st["running"][app].append(r)
        else:
            v.prefix_detach(r)
            if s is not None:
                s.park_cancel(v, r.req_id)
    st["parked"][app] = []
    if s is not None:
        s.unpark_done(v, app)


def _op_evict(st) -> None:
    st["shared"]._evict_prefix(1)


def _op_pin(st, app) -> None:
    pins = st["pins"]
    if pins:
        st["unpins"] += st["cache"].unpin(pins.pop().nodes)
        return
    m = st["cache"].pin(st["prompts"]["a"])
    if m.nodes:
        pins.append(m)


def _op_donate(st, app) -> None:
    """Mirror PagedRunner._prefix_insert's full-page accounting: move
    freshly 'prefilled' prompt pages from the donor's quota into the
    shared cache, pinned for the donor."""
    from repro.serving.kv_cache import PAGE_SIZE
    v = st["views"][app]
    cache = st["cache"]
    for r in st["running"][app]:
        n_full = r.prompt_len // PAGE_SIZE
        n_att = len(r.shared_pages)
        if n_att >= n_full:
            continue
        n_new, _ = cache.probe_new(r.prompt_tokens, n_att)
        if n_new == 0 or len(r.pages) < n_new:
            continue
        phys = v.cache_donate(r.pages[:n_new])
        del r.pages[:n_new]
        r.shared_pages.extend(phys)
        created = cache.insert(r.prompt_tokens[:n_full * PAGE_SIZE],
                               n_att, phys)
        r.prefix_nodes = (r.prefix_nodes or []) + created
        return


def _apply(st, op: str) -> None:
    kind, _, app = op.partition("_")
    if kind == "grant":
        _op_grant(st, app)
    elif kind == "preempt":
        _op_preempt(st, app)
    elif kind == "park":
        _op_park(st, app)
    elif kind == "unpark":
        _op_unpark(st, app)
    elif kind == "evict":
        _op_evict(st)
    elif kind == "pin":
        _op_pin(st, app)
    elif kind == "donate":
        _op_donate(st, app)
    else:
        raise ValueError(f"unknown explore op {op!r}")


def explore(depth: int = 3, ops=EXPLORE_OPS,
            pool_pages: int = 12) -> ExploreResult:
    """Replay EVERY ``len(ops) ** depth`` interleaving of the
    arbitration ops against a fresh two-tenant model pool, running the
    full ledger check after every single op.  A bounded model checker:
    any reachable accounting bug within ``depth`` steps of a clean pool
    surfaces as a named violation with its schedule's call site.

    Installs its own non-strict sanitizer for the sweep (so one bad
    schedule doesn't hide the rest) and restores the previous one."""
    import itertools

    prev = SAN
    san = enable(strict=False)
    applied = sequences = 0
    try:
        for seq in itertools.product(ops, repeat=depth):
            st = _model_state(pool_pages)
            sequences += 1
            for op in seq:
                _apply(st, op)
                applied += 1
                san.check(st["views"]["a"])
    finally:
        _install(prev)
    return ExploreResult(depth=depth, sequences=sequences,
                         ops_applied=applied,
                         violations=list(san.violations))


# -- env gate (mirrors repro.obs: the ONLY activation cost when unset is
#    this import-time check) -------------------------------------------------
if os.environ.get("REPRO_ZENSAN", "") not in ("", "0"):
    enable(strict=True,
           report_path=os.environ.get("REPRO_ZENSAN_REPORT") or None)
