"""CLI: ``python -m repro.analysis <paths...>``.

Exit status 1 on any unsuppressed finding (the CI zenlint gate), 0 on a
clean tree.  Suppressed findings are listed (with their justification)
when ``--show-suppressed`` is given and always counted in the per-rule
summary, so the job log records how many invariant exceptions the tree
carries and why.

``--format`` selects the output encoding without touching the exit
codes: ``text`` (default, human-readable + per-rule summary), ``json``
(one machine-readable document for dashboards/diffing), ``github``
(workflow-command annotations -- ``::error`` per open finding,
``::notice`` per suppressed one -- so findings surface inline on the
PR diff).  ``--strict-suppressions`` additionally fails the gate on
stale directives that no longer suppress anything.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List

from repro.analysis.engine import (ENGINE_RULE, Finding, analyze_paths,
                                   default_rules)


def summarize(findings: List[Finding]) -> str:
    rules = {r.rule_id: r.title for r in default_rules()}
    rules[ENGINE_RULE] = "analyzer diagnostics (unsuppressable)"
    lines = [f"{'rule':<7} {'open':>5} {'suppressed':>11}  invariant"]
    for rid in sorted(rules):
        open_n = sum(1 for f in findings
                     if f.rule == rid and not f.suppressed)
        sup_n = sum(1 for f in findings if f.rule == rid and f.suppressed)
        lines.append(f"{rid:<7} {open_n:>5} {sup_n:>11}  {rules[rid]}")
    return "\n".join(lines)


def render_json(findings: List[Finding]) -> str:
    open_n = sum(1 for f in findings if not f.suppressed)
    doc = {
        "findings": [
            {"rule": f.rule, "path": f.path, "line": f.line,
             "message": f.message, "suppressed": f.suppressed,
             "reason": f.reason}
            for f in findings
        ],
        "open": open_n,
        "suppressed": len(findings) - open_n,
        "ok": open_n == 0,
    }
    return json.dumps(doc, indent=2, sort_keys=True)


def _gh_escape(text: str) -> str:
    """GitHub workflow-command data escaping (the documented set)."""
    return (text.replace("%", "%25").replace("\r", "%0D")
            .replace("\n", "%0A"))


def render_github(findings: List[Finding],
                  show_suppressed: bool) -> List[str]:
    lines = []
    for f in findings:
        if f.suppressed and not show_suppressed:
            continue
        level = "notice" if f.suppressed else "error"
        msg = f.message if not f.suppressed else (
            f"{f.message} [suppressed: {f.reason}]")
        lines.append(f"::{level} file={f.path},line={f.line},"
                     f"title=zenlint {f.rule}::{_gh_escape(msg)}")
    return lines


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="zenlint: AST invariant analysis (page-id provenance, "
                    "jit donation/recompile hazards, host-sync-free hot "
                    "paths, pool-accounting pairing)")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to analyze")
    ap.add_argument("--rule", action="append", default=None,
                    metavar="ZL00x",
                    help="run only these rule ids (repeatable)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings with reasons")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text",
                    help="output encoding (exit codes are identical): "
                         "human text, one JSON document, or GitHub "
                         "workflow-command annotations")
    ap.add_argument("--strict-suppressions", action="store_true",
                    help="also fail on stale directives that no longer "
                         "suppress any finding")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    rules = default_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.rule_id}  {r.title}")
        return 0
    if args.rule:
        wanted = {r.upper() for r in args.rule}
        rules = [r for r in rules if r.rule_id in wanted]
        unknown = wanted - {r.rule_id for r in rules}
        if unknown:
            print(f"unknown rule id(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2

    findings = analyze_paths(args.paths, rules,
                             strict_suppressions=args.strict_suppressions)
    open_findings = [f for f in findings if not f.suppressed]
    if args.format == "json":
        print(render_json(findings))
    elif args.format == "github":
        for line in render_github(findings, args.show_suppressed):
            print(line)
        print(f"zenlint: {'FAIL' if open_findings else 'OK'} "
              f"({len(open_findings)} open finding(s), "
              f"{len(findings) - len(open_findings)} suppressed)")
    else:
        for f in findings:
            if not f.suppressed or args.show_suppressed:
                print(f.render())
        print()
        print(summarize(findings))
        print(f"\nzenlint: {'FAIL' if open_findings else 'OK'} "
              f"({len(open_findings)} open finding(s), "
              f"{len(findings) - len(open_findings)} suppressed)")
    return 1 if open_findings else 0


if __name__ == "__main__":
    sys.exit(main())
