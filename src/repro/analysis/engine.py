"""zenlint rule engine: files -> ASTs -> findings, with suppressions.

The analyzer exists because Zenix's hardest invariants are invisible to
the type system: view-local vs physical page ids are both ``List[int]``,
a donated jit buffer is an ordinary attribute, and a host sync is one
innocuous ``int()``.  Each rule in :mod:`repro.analysis.rules` encodes
one such invariant as an AST check; this module carries everything the
rules share:

* :class:`Finding` -- one diagnostic, addressable as ``path:line``.
* :class:`Module` -- a parsed file: source, AST, per-line suppressions,
  and the AST helpers every rule needs (dotted paths, function walks,
  jit registries).
* suppression parsing -- ``# zenlint: ignore[ZL001] -- reason`` on the
  offending line (or as a standalone comment on the line above).  The
  ``-- reason`` text is MANDATORY: a reasonless suppression does not
  suppress, it adds an extra ZL000 finding, so "zero unjustified
  suppressions" is machine-checkable.
* :func:`analyze_paths` / :func:`analyze_source` -- the drivers the CLI
  and the fixture tests run.

Rules are heuristic by design (naming + call-graph conventions of THIS
repo), so every rule must hold two properties: a violation of the
written convention is flagged, and the idiomatic correct pattern is not.
Both are pinned by fixture tests per rule.
"""

from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

#: rule id of engine-level diagnostics (parse errors, bad suppressions);
#: deliberately NOT suppressible -- the mechanism must not hide its own
#: failures.
ENGINE_RULE = "ZL000"

_SUPPRESS_RE = re.compile(
    r"#\s*zenlint:\s*ignore\[([A-Za-z0-9_,\s]+)\]\s*(?:--\s*(\S.*))?")


@dataclass(frozen=True)
class Finding:
    """One diagnostic: ``path:line: rule message``."""

    rule: str
    path: str
    line: int
    message: str
    suppressed: bool = False
    reason: str = ""

    def render(self) -> str:
        tail = f"  [suppressed: {self.reason}]" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule} {self.message}{tail}"


class Rule:
    """One invariant.  Subclasses yield ``(line, message)`` pairs."""

    rule_id = ENGINE_RULE
    title = ""

    def run(self, mod: "Module") -> Iterator[Tuple[int, str]]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# AST helpers shared by the rules
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """``self.store.k_pages`` for the matching Attribute/Name chain, or
    None when the expression is not a plain dotted path."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def last_name(node: ast.AST) -> Optional[str]:
    """Final component of a dotted path (``self._decode`` -> ``_decode``)."""
    d = dotted(node)
    return None if d is None else d.rsplit(".", 1)[-1]


def call_last_name(call: ast.Call) -> Optional[str]:
    return last_name(call.func)


def contains(node: ast.AST, pred) -> bool:
    return any(pred(n) for n in ast.walk(node))


def loads_path(node: ast.AST, path: str) -> bool:
    """Whether ``node`` reads dotted ``path`` (or subscripts into it)."""
    def hit(n):
        return (isinstance(n, (ast.Name, ast.Attribute))
                and isinstance(getattr(n, "ctx", None), ast.Load)
                and dotted(n) == path)
    return contains(node, hit)


def stmt_exprs(stmt: ast.stmt) -> List[ast.AST]:
    """The expressions belonging to ``stmt`` ITSELF: the whole node for
    simple statements, only the header expressions for compound ones
    (whose body statements a linearized walk visits separately -- walking
    the whole compound would double-count every nested expression)."""
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.target, stmt.iter]
    if isinstance(stmt, (ast.While, ast.If)):
        return [stmt.test]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [it.context_expr for it in stmt.items]
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef, ast.Try)):
        return []
    return [stmt]


def stmt_calls(stmt: ast.stmt) -> Iterator[ast.Call]:
    """Every Call in the statement's OWN expressions (see stmt_exprs)."""
    for expr in stmt_exprs(stmt):
        for n in ast.walk(expr):
            if isinstance(n, ast.Call):
                yield n


@dataclass
class FuncInfo:
    """One function/method with enough context for hot-path decisions."""

    node: ast.AST                      # FunctionDef | AsyncFunctionDef
    name: str
    qualname: str
    cls: Optional[str] = None          # enclosing class name, if any

    def statements(self) -> List[ast.stmt]:
        """Every statement in the body, linearized in source order (the
        rules reason about 'after the call' lexically -- a deliberate
        approximation of control flow)."""
        out = [n for n in ast.walk(self.node) if isinstance(n, ast.stmt)]
        out.remove(self.node)  # the def itself
        return sorted(out, key=lambda n: (n.lineno, n.col_offset))


def own_statements(node: ast.AST) -> List[ast.stmt]:
    """The function's OWN statements in source order -- unlike
    FuncInfo.statements() this does not descend into nested defs or
    classes, whose returns and bindings belong to a different frame.
    The interprocedural summaries (ZL001/ZL005) need this distinction:
    a nested closure's ``return`` says nothing about the enclosing
    function's return value."""
    out: List[ast.stmt] = []

    def visit(n):
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            if isinstance(child, ast.stmt):
                out.append(child)
            visit(child)

    visit(node)
    return sorted(out, key=lambda n: (n.lineno, n.col_offset))


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def _comment_tokens(source: str) -> Iterator[Tuple[int, int, str]]:
    """(line, col, text) of every real COMMENT token -- tokenizing (not
    regexing raw lines) so directives *mentioned* in docstrings don't
    count as directives."""
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.start[1], tok.string
    except (tokenize.TokenError, IndentationError):
        return


class Suppressions:
    """Per-line ``# zenlint: ignore[...]`` directives of one file.

    A trailing directive covers the physical line it sits on.  A
    standalone directive (a comment-only line) covers the next CODE
    line: blank lines and further comment lines are skipped, so a
    multi-line justification block works -- put the directive on the
    block's first line and the prose after the ``--``/on the following
    comment lines."""

    def __init__(self, source: str):
        self.by_line: Dict[int, Tuple[Set[str], str]] = {}
        self.unjustified: List[Tuple[int, str]] = []
        #: (line, rule) pairs that actually suppressed a finding --
        #: the complement is the stale set ``--strict-suppressions``
        #: reports
        self.used: Set[Tuple[int, str]] = set()
        lines = source.splitlines()
        comments = list(_comment_tokens(source))
        comment_only = {ln for ln, col, _ in comments
                        if lines[ln - 1][:col].strip() == ""}
        for lineno, col, text in comments:
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = {r.strip().upper() for r in m.group(1).split(",")
                     if r.strip()}
            reason = (m.group(2) or "").strip()
            if not reason:
                self.unjustified.append((lineno, ",".join(sorted(rules))))
                continue
            target = lineno
            if lineno in comment_only:
                target = lineno + 1
                while (target <= len(lines)
                       and (target in comment_only
                            or not lines[target - 1].strip())):
                    target += 1
            prev = self.by_line.get(target)
            if prev:
                rules = rules | prev[0]
                reason = f"{prev[1]}; {reason}"
            self.by_line[target] = (rules, reason)

    def reason_for(self, rule: str, line: int) -> Optional[str]:
        hit = self.by_line.get(line)
        if hit and rule.upper() in hit[0]:
            self.used.add((line, rule.upper()))
            return hit[1]
        return None

    def stale(self, ran_rules: Set[str]) -> Iterator[Tuple[int, str]]:
        """Directives that suppressed NOTHING this run, restricted to
        the rules that actually ran (a ``--rule``-filtered run must not
        call another rule's directive stale)."""
        for line, (rules, _reason) in sorted(self.by_line.items()):
            for rid in sorted(rules):
                if rid in ran_rules and (line, rid) not in self.used:
                    yield line, rid


# ---------------------------------------------------------------------------
# module context
# ---------------------------------------------------------------------------

@dataclass
class JitInfo:
    """One ``X = jax.jit(fn, ...)`` binding found in a module."""

    target: str                        # dotted target path (self._decode)
    name: str                          # its last component (_decode)
    line: int
    donate: Tuple[int, ...] = ()       # donate_argnums
    donate_names: Tuple[str, ...] = () # donate_argnames
    static: Tuple[int, ...] = ()       # static_argnums
    static_names: Tuple[str, ...] = ()


def _int_tuple(node: ast.AST) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
        return tuple(out)
    return ()


def _str_tuple(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str))
    return ()


def parse_jit_call(call: ast.Call) -> Optional[Dict]:
    """jit parameters of a ``jax.jit(...)``/``jit(...)`` call, else None."""
    if call_last_name(call) != "jit":
        return None
    info = {"donate": (), "donate_names": (), "static": (),
            "static_names": ()}
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            info["donate"] = _int_tuple(kw.value)
        elif kw.arg == "donate_argnames":
            info["donate_names"] = _str_tuple(kw.value)
        elif kw.arg == "static_argnums":
            info["static"] = _int_tuple(kw.value)
        elif kw.arg == "static_argnames":
            info["static_names"] = _str_tuple(kw.value)
    return info


class Module:
    """A parsed source file plus the shared per-module indexes."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.suppressions = Suppressions(source)
        self._jit: Optional[Dict[str, JitInfo]] = None

    # -- function iteration --------------------------------------------------
    def functions(self) -> Iterator[FuncInfo]:
        def visit(node: ast.AST, prefix: str, cls: Optional[str]):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    qn = f"{prefix}{child.name}"
                    yield FuncInfo(child, child.name, qn, cls)
                    yield from visit(child, qn + ".", cls)
                elif isinstance(child, ast.ClassDef):
                    yield from visit(child, f"{prefix}{child.name}.",
                                     child.name)
        yield from visit(self.tree, "", None)

    # -- jit registry (ZL002/ZL003/ZL004 share it) ---------------------------
    def jit_bindings(self) -> Dict[str, JitInfo]:
        """Every ``<target> = jax.jit(...)`` in the module, keyed by the
        target's LAST name: methods bind ``self._decode`` in ``__init__``
        and call ``self._decode`` elsewhere, so the last component is the
        stable join key."""
        if self._jit is not None:
            return self._jit
        out: Dict[str, JitInfo] = {}
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            target = dotted(node.targets[0])
            if target is None or not isinstance(node.value, ast.Call):
                continue
            info = parse_jit_call(node.value)
            if info is None:
                continue
            name = target.rsplit(".", 1)[-1]
            out[name] = JitInfo(target=target, name=name, line=node.lineno,
                                **info)
        self._jit = out
        return out


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def default_rules() -> List[Rule]:
    from repro.analysis.rules import ALL_RULES
    return [cls() for cls in ALL_RULES]


def analyze_source(source: str, path: str = "<string>",
                   rules: Optional[Sequence[Rule]] = None,
                   strict_suppressions: bool = False) -> List[Finding]:
    """Findings of one source blob (suppressions applied, engine
    diagnostics included).  The fixture tests drive this directly.
    With ``strict_suppressions`` a directive that suppressed nothing is
    itself a finding: stale suppressions hide regressions of the VERY
    invariant they once excused, because the next real finding on that
    line inherits the old justification unseen."""
    rules = list(rules) if rules is not None else default_rules()
    try:
        mod = Module(path, source)
    except SyntaxError as e:
        return [Finding(ENGINE_RULE, path, e.lineno or 1,
                        f"parse error: {e.msg}")]
    findings: List[Finding] = []
    for lineno, ruleset in mod.suppressions.unjustified:
        findings.append(Finding(
            ENGINE_RULE, path, lineno,
            f"suppression of [{ruleset}] without a '-- reason': a "
            "justification is mandatory (and this directive is ignored)"))
    for rule in rules:
        seen = set()
        for line, message in rule.run(mod):
            if (line, message) in seen:
                continue
            seen.add((line, message))
            reason = mod.suppressions.reason_for(rule.rule_id, line)
            findings.append(Finding(rule.rule_id, path, line, message,
                                    suppressed=reason is not None,
                                    reason=reason or ""))
    if strict_suppressions:
        ran = {r.rule_id for r in rules}
        for line, rid in mod.suppressions.stale(ran):
            findings.append(Finding(
                ENGINE_RULE, path, line,
                f"stale suppression of [{rid}]: no {rid} finding on "
                "this line -- the invariant holds again, delete the "
                "directive before it silently excuses the next real "
                "finding"))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def analyze_paths(paths: Iterable[str],
                  rules: Optional[Sequence[Rule]] = None,
                  strict_suppressions: bool = False) -> List[Finding]:
    rules = list(rules) if rules is not None else default_rules()
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        with open(path, encoding="utf-8") as f:
            source = f.read()
        findings.extend(analyze_source(source, path, rules,
                                       strict_suppressions))
    return findings
