"""Heuristics shared by several rules: what is a hot path, and what
expressions smell like per-request values vs bucketed ones.

These encode *this repo's* conventions (they are what make the rules
precise enough to gate CI):

* hot paths are the per-token serving functions -- ``decode``/``prefill``
  (and their jit-traced ``_fn`` bodies) on ``*Runner`` classes, and the
  per-tick methods of ``*Engine`` classes.  Everything there runs once
  per generated token across every request of a pod.
* per-request values are expressions rooted at a request object
  (``req``/``r``/``request``) or at the engine's ``running``/``requests``
  lists -- exactly the values that vary call-to-call and must therefore
  never become a jit compile key.
* bucketing launders a per-request value into an O(1)-cardinality one:
  a floor division (page math: ``// PAGE_SIZE``) or one of the explicit
  helpers (``_next_pow2``, anything with ``bucket`` in its name).
"""

from __future__ import annotations

import ast
from typing import Dict, Optional, Set

from repro.analysis.engine import FuncInfo, dotted

#: method names that are hot per class-name suffix
HOT_METHODS = {
    "Runner": {"decode", "prefill", "_decode_fn", "_prefill_fn"},
    "Engine": {"step", "_admit", "_reclaim", "preempt", "preempt_newest"},
}

#: names that (by convention) hold a Request / the running-request list
REQUEST_ROOTS = {"req", "r", "request", "victim", "running", "requests"}

#: calls that turn a per-request value into a bounded compile key
BUCKET_HELPERS = ("_next_pow2", "next_pow2")

#: builtin reducers whose results are scalars -- a traced scalar is not
#: a shape, so names assigned from these never carry the 'request' mark
SCALAR_BUILTINS = {"max", "min", "len", "sum", "int", "float", "abs",
                   "round", "bool"}


def is_hot_path(func: FuncInfo) -> bool:
    if func.cls is not None:
        for suffix, methods in HOT_METHODS.items():
            if func.cls.endswith(suffix) and func.name in methods:
                return True
    return False


def _root(path: str) -> str:
    return path.split(".", 1)[0]


def is_request_derived(node: ast.AST,
                       env: Optional[Dict[str, str]] = None) -> bool:
    """Whether the expression (transitively) reads per-request data:
    a dotted path rooted at a request-ish name, or a name the caller's
    ``env`` already classified as request-derived."""
    env = env or {}
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            if n.id in REQUEST_ROOTS or env.get(n.id) == "request":
                return True
        elif isinstance(n, ast.Attribute):
            d = dotted(n)
            if d is not None and _root(d) in REQUEST_ROOTS:
                return True
    return False


def is_bucketed(node: ast.AST,
                env: Optional[Dict[str, str]] = None) -> bool:
    """Whether the expression passes through a bucketing step (floor
    division or an explicit bucket helper), directly or via a name the
    caller's ``env`` classified as bucketed."""
    env = env or {}
    for n in ast.walk(node):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.FloorDiv):
            return True
        if isinstance(n, ast.Name) and env.get(n.id) == "bucketed":
            return True
        if isinstance(n, ast.Call):
            callee = dotted(n.func)
            if callee is not None:
                leaf = callee.rsplit(".", 1)[-1]
                if leaf in BUCKET_HELPERS or "bucket" in leaf:
                    return True
    return False


def classify_env(func: FuncInfo) -> Dict[str, str]:
    """Name -> 'bucketed' | 'request' for simple assignments, in source
    order (bucketed wins: laundering is the point of the helpers)."""
    env: Dict[str, str] = {}
    for stmt in func.statements():
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            continue
        t = stmt.targets[0]
        if not isinstance(t, ast.Name):
            continue
        value = stmt.value
        scalar = (isinstance(value, ast.Call)
                  and isinstance(value.func, ast.Name)
                  and value.func.id in SCALAR_BUILTINS)
        if is_bucketed(value, env):
            env[t.id] = "bucketed"
        elif is_request_derived(value, env) and not scalar:
            env[t.id] = "request"
        else:
            env.pop(t.id, None)
    return env


def assigned_names(target: ast.AST) -> Set[str]:
    """Flat set of dotted paths a (possibly tuple) target binds."""
    out: Set[str] = set()
    if isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            out |= assigned_names(e)
    else:
        d = dotted(target)
        if d is not None:
            out.add(d)
    return out
