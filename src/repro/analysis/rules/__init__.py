"""The zenlint rule set.  Each module encodes one invariant of the
paged serving data plane; ``ALL_RULES`` is the registry the engine and
the CLI instantiate.  See ``docs/analysis.md`` for the catalogue
(invariant, example violation, correct pattern, suppression) per rule.
"""

from repro.analysis.rules.accounting import AccountingPairing
from repro.analysis.rules.donation import DonationAfterUse
from repro.analysis.rules.hostsync import HostSyncInHotPath
from repro.analysis.rules.provenance import PageIdProvenance
from repro.analysis.rules.recompile import RecompileHazard

ALL_RULES = [PageIdProvenance, DonationAfterUse, RecompileHazard,
             HostSyncInHotPath, AccountingPairing]

__all__ = ["ALL_RULES", "PageIdProvenance", "DonationAfterUse",
           "RecompileHazard", "HostSyncInHotPath", "AccountingPairing"]
