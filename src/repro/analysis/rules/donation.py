"""ZL002: donation-after-use -- reading a buffer after jit donated it.

The paged hot path only avoids copying the whole KV pool per token
because the page arrays are **donated** to the jitted step functions
(``donate_argnums``): XLA reuses the input buffer for the output, and
the Python-side array object passed in becomes INVALID the moment the
call runs.  The safe idiom is rebinding from the call's own result::

    nxt, self.store.k_pages, self.store.v_pages = self._decode(
        ..., self.store.k_pages, self.store.v_pages)

Reading the donated path afterwards *without* that rebinding returns
garbage (or raises, backend-dependent) -- and only under jit, so a test
running un-jitted never sees it.  This rule finds every module-level
``X = jax.jit(fn, donate_argnums=...)`` binding, then flags any read of
a donated argument's dotted path after a call to ``X`` in the same
function, unless the path was rebound first (by the call's own
assignment targets or a later store).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.engine import (Module, Rule, dotted, loads_path,
                                   stmt_exprs)
from repro.analysis.rules.common import assigned_names


def _donated_paths(call: ast.Call, donate: Tuple[int, ...],
                   donate_names: Tuple[str, ...]) -> List[str]:
    """Dotted paths of the donated arguments at this call site (non-path
    expressions -- subscripts, temporaries -- can't be re-read and are
    skipped)."""
    out = []
    for idx in donate:
        if idx < len(call.args):
            d = dotted(call.args[idx])
            if d is not None:
                out.append(d)
    for kw in call.keywords:
        if kw.arg in donate_names:
            d = dotted(kw.value)
            if d is not None:
                out.append(d)
    return out


class DonationAfterUse(Rule):
    rule_id = "ZL002"
    title = "donated jit buffers read without rebinding"

    def run(self, mod: Module) -> Iterator[Tuple[int, str]]:
        donors = {name: info for name, info in mod.jit_bindings().items()
                  if info.donate or info.donate_names}
        if not donors:
            return
        for func in mod.functions():
            # dead[path] = (donating callee, call line); cleared on rebind
            dead: Dict[str, Tuple[str, int]] = {}
            for stmt in func.statements():
                stores = set()
                if isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        stores |= assigned_names(t)
                elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                    d = dotted(stmt.target)
                    if d is not None:
                        stores.add(d)
                # reads of a still-dead donated path (the statement's own
                # expressions only; nested statements get their own turn).
                # The donating call's own statement is exempt: its reads
                # ARE the call arguments.
                for path, (callee, line) in list(dead.items()):
                    for expr in stmt_exprs(stmt):
                        if loads_path(expr, path):
                            yield (stmt.lineno,
                                   f"'{path}' was donated to {callee}() at "
                                   f"line {line} and is read here before "
                                   "being rebound -- donated buffers are "
                                   "invalidated by XLA; rebind from the "
                                   "call's result")
                            break
                # rebinding revives the path
                for path in stores:
                    dead.pop(path, None)
                # new donations from calls in this statement
                newly: Dict[str, Tuple[str, int]] = {}
                for expr in stmt_exprs(stmt):
                    for call in (n for n in ast.walk(expr)
                                 if isinstance(n, ast.Call)):
                        callee = _callee_name(call)
                        info = donors.get(callee) if callee else None
                        if info is None:
                            continue
                        for path in _donated_paths(call, info.donate,
                                                   info.donate_names):
                            newly[path] = (callee, stmt.lineno)
                for path, origin in newly.items():
                    if path not in stores:   # call's own targets rebind
                        dead[path] = origin


def _callee_name(call: ast.Call) -> Optional[str]:
    d = dotted(call.func)
    return None if d is None else d.rsplit(".", 1)[-1]
