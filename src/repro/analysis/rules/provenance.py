"""ZL001: page-id provenance -- view-local ids vs physical ids.

The isolation boundary of multi-tenant serving is a *unit system*:
requests hold **view-local** page ids (``req.pages``/``req.local_pages``,
everything a ``PoolView``/``PagePool`` grant returns), while the device
page arrays, the shared free lists, and the decode kernel's page tables
speak **physical** ids.  ``to_physical``/``to_physical_local`` (and the
runner's ``_phys``/``_phys_local`` wrappers) are the only conversion --
and it raises on ids the view no longer owns, which is the whole guard.

Mixing the units never fails loudly on a private pool (the remap is the
identity there), so the bug ships and only detonates under tenancy.
This rule flow-tracks both taints per function and flags:

* a view-local value reaching a physical sink: ``page_table(pages=...)``,
  ``SharedPagePool._give``, or a shared free list's ``extend``;
* a physical value stored back onto a request (``req.pages = phys`` /
  ``req.pages.extend(phys)``) -- requests must only ever hold view ids;
* a physical value translated *again* through ``to_physical*`` -- double
  translation reads some other tenant's pages when ids happen to alias.

The prefix cache (serving/prefix_cache.py) introduces a SECOND class of
physical ids that legitimately lives on requests: ``req.shared_pages``
holds cache-owned physical page ids (and ``PrefixMatch.phys_pages`` is
their source).  These are recognized provenance sources -- reading them
taints PHYS, so translating them again or freeing them as view ids is
flagged -- and the dual sinks hold: a VIEW value assigned or extended
into ``shared_pages`` is flagged (the cache speaks physical only;
``cache_donate`` is the conversion, ``cow_grant`` returns view ids).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Tuple

from repro.analysis.engine import Module, Rule, dotted, stmt_calls

VIEW = "view-local"
PHYS = "physical"

#: grant APIs: whatever they return is what requests hold (view ids);
#: cow_grant is a one-page grant from the request's own pool/view
VIEW_CALLS = {"_alloc", "_alloc_local", "_new_ids", "cow_grant"}
#: translation / physical-side APIs: results are physical ids;
#: cache_donate converts view ids to physical as ownership moves to the
#: prefix cache
PHYS_CALLS = {"to_physical", "to_physical_local", "_phys", "_phys_local",
              "reclaim", "_take", "cache_donate"}
#: remap tables: indexing or popping one yields a physical id
REMAP_NAMES = {"_remap", "_remap_local"}
#: request attributes that hold view-local ids
REQ_ID_ATTRS = ("pages", "local_pages")
#: attributes that hold cache-owned PHYSICAL ids (prefix cache): reading
#: one taints physical; writing view ids into one is a sink
PHYS_ATTRS = ("shared_pages", "phys_pages")
#: physical-side free lists: extending one with view ids corrupts the pool
PHYS_FREE_NAMES = {"free_local"}

#: pass-through wrappers: taint flows through the first argument
TRANSPARENT_CALLS = {"list", "sorted", "reversed", "tuple", "asarray",
                     "array"}


def _leaf(path: Optional[str]) -> Optional[str]:
    return None if path is None else path.rsplit(".", 1)[-1]


class PageIdProvenance(Rule):
    rule_id = "ZL001"
    title = "view-local vs physical page-id provenance"

    # -- expression taint ---------------------------------------------------
    def _taint(self, node: ast.AST, env: Dict[str, str]) -> Optional[str]:
        if isinstance(node, (ast.Name, ast.Attribute)):
            d = dotted(node)
            if d is None:
                return None
            if d in env:
                return env[d]
            if _leaf(d) in PHYS_ATTRS and "." in d:
                return PHYS
            if _leaf(d) in REQ_ID_ATTRS and "." in d:
                return VIEW
            return None
        if isinstance(node, ast.Call):
            leaf = _leaf(dotted(node.func))
            if leaf in PHYS_CALLS:
                return PHYS
            if leaf in VIEW_CALLS:
                return VIEW
            if leaf == "pop":
                base = _leaf(dotted(getattr(node.func, "value", None)))
                if base in REMAP_NAMES:
                    return PHYS
            if leaf in TRANSPARENT_CALLS and node.args:
                return self._taint(node.args[0], env)
            return None
        if isinstance(node, ast.Subscript):
            base = _leaf(dotted(node.value))
            if base in REMAP_NAMES:
                return PHYS
            return self._taint(node.value, env)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            inner = dict(env)
            for gen in node.generators:
                if isinstance(gen.target, ast.Name):
                    t = self._taint(gen.iter, env)
                    if t is not None:
                        inner[gen.target.id] = t
            return self._taint(node.elt, inner)
        if isinstance(node, ast.BinOp):
            return (self._taint(node.left, env)
                    or self._taint(node.right, env))
        if isinstance(node, ast.IfExp):
            a = self._taint(node.body, env)
            b = self._taint(node.orelse, env)
            return a if a == b else (a or b)
        if isinstance(node, ast.Starred):
            return self._taint(node.value, env)
        return None

    # -- sinks --------------------------------------------------------------
    def _check_call(self, call: ast.Call,
                    env: Dict[str, str]) -> Iterator[Tuple[int, str]]:
        leaf = _leaf(dotted(call.func))
        if leaf == "page_table":
            for kw in call.keywords:
                if kw.arg == "pages" and self._taint(kw.value, env) == VIEW:
                    yield (kw.value.lineno,
                           "view-local page ids reach page_table(pages=...):"
                           " the kernel indexes the device arrays by "
                           "PHYSICAL ids -- translate via "
                           "pool.to_physical() first")
        elif leaf == "_give":
            for arg in call.args:
                if self._taint(arg, env) == VIEW:
                    yield (arg.lineno,
                           "view-local ids returned to the shared pool's "
                           "physical free list (_give): translate via the "
                           "remap before freeing")
        elif leaf in ("to_physical", "to_physical_local",
                      "_phys", "_phys_local"):
            for arg in call.args:
                if self._taint(arg, env) == PHYS:
                    yield (arg.lineno,
                           f"already-physical ids translated again through "
                           f"{leaf}(): double translation resolves through "
                           "the wrong view's remap")
        elif leaf == "extend":
            base = dotted(getattr(call.func, "value", None))
            if (_leaf(base) in PHYS_FREE_NAMES and call.args
                    and self._taint(call.args[0], env) == VIEW):
                yield (call.lineno,
                       "view-local ids pushed onto a physical free list "
                       f"({base}.extend): free the PHYSICAL ids instead")
            if (base is not None and _leaf(base) in REQ_ID_ATTRS
                    and "." in base and call.args
                    and self._taint(call.args[0], env) == PHYS):
                yield (call.lineno,
                       f"physical ids appended to {base}: requests must "
                       "hold view-local ids only (grants already return "
                       "them)")
            if (base is not None and _leaf(base) in PHYS_ATTRS
                    and "." in base and call.args
                    and self._taint(call.args[0], env) == VIEW):
                yield (call.lineno,
                       f"view-local ids appended to {base}: the prefix "
                       "cache holds PHYSICAL ids only -- convert via "
                       "cache_donate()/to_physical() first")

    # -- driver -------------------------------------------------------------
    def run(self, mod: Module) -> Iterator[Tuple[int, str]]:
        for func in mod.functions():
            env: Dict[str, str] = {}
            for stmt in func.statements():
                # sinks first: the env of a statement is everything bound
                # strictly before it
                for call in stmt_calls(stmt):
                    yield from self._check_call(call, env)
                if isinstance(stmt, ast.Assign):
                    targets = stmt.targets
                    if (len(targets) == 1
                            and isinstance(targets[0], (ast.Tuple, ast.List))
                            and isinstance(stmt.value,
                                           (ast.Tuple, ast.List))
                            and len(targets[0].elts)
                            == len(stmt.value.elts)):
                        pairs = zip(targets[0].elts, stmt.value.elts)
                    elif len(targets) == 1:
                        pairs = [(targets[0], stmt.value)]
                    else:
                        pairs = [(t, stmt.value) for t in targets]
                    for tgt, val in pairs:
                        d = dotted(tgt)
                        if d is None:
                            continue
                        t = self._taint(val, env)
                        if (_leaf(d) in REQ_ID_ATTRS and "." in d
                                and t == PHYS):
                            yield (stmt.lineno,
                                   f"physical ids stored on {d}: requests "
                                   "must hold view-local ids (the remap is "
                                   "the isolation boundary)")
                        if (_leaf(d) in PHYS_ATTRS and "." in d
                                and t == VIEW):
                            yield (stmt.lineno,
                                   f"view-local ids stored on {d}: the "
                                   "prefix cache's pages are PHYSICAL -- "
                                   "a view id here reads another tenant's "
                                   "pages when ids alias")
                        if t is None:
                            env.pop(d, None)
                        else:
                            env[d] = t
                elif isinstance(stmt, ast.For):
                    if isinstance(stmt.target, ast.Name):
                        t = self._taint(stmt.iter, env)
                        if t is not None:
                            env[stmt.target.id] = t
