"""ZL001: page-id provenance -- view-local ids vs physical ids.

The isolation boundary of multi-tenant serving is a *unit system*:
requests hold **view-local** page ids (``req.pages``/``req.local_pages``,
everything a ``PoolView``/``PagePool`` grant returns), while the device
page arrays, the shared free lists, and the decode kernel's page tables
speak **physical** ids.  ``to_physical``/``to_physical_local`` (and the
runner's ``_phys``/``_phys_local`` wrappers) are the only conversion --
and it raises on ids the view no longer owns, which is the whole guard.

Mixing the units never fails loudly on a private pool (the remap is the
identity there), so the bug ships and only detonates under tenancy.
This rule flow-tracks both taints per function and flags:

* a view-local value reaching a physical sink: ``page_table(pages=...)``,
  ``SharedPagePool._give``, or a shared free list's ``extend``;
* a physical value stored back onto a request (``req.pages = phys`` /
  ``req.pages.extend(phys)``) -- requests must only ever hold view ids;
* a physical value translated *again* through ``to_physical*`` -- double
  translation reads some other tenant's pages when ids happen to alias.

The prefix cache (serving/prefix_cache.py) introduces a SECOND class of
physical ids that legitimately lives on requests: ``req.shared_pages``
holds cache-owned physical page ids (and ``PrefixMatch.phys_pages`` is
their source).  These are recognized provenance sources -- reading them
taints PHYS, so translating them again or freeing them as view ids is
flagged -- and the dual sinks hold: a VIEW value assigned or extended
into ``shared_pages`` is flagged (the cache speaks physical only;
``cache_donate`` is the conversion, ``cow_grant`` returns view ids).

**Interprocedural flow**: ids routinely cross helper boundaries --
``def _free_pages(pool, ids): pool._give(ids)`` called with
``req.pages`` is the same bug as the inline version, invisible to a
per-function pass.  The rule therefore builds a module-level summary of
every locally defined, unambiguously named function: (a) the taint its
return value carries (fixed VIEW/PHYS, or pass-through of parameter i),
and (b) which parameters reach a physical sink (flagging VIEW
arguments) or a re-translation (flagging PHYS arguments) inside the
body.  Summaries are iterated to a fixpoint so taint follows chains of
helpers; the known-name sets above always take precedence over
summaries, and ambiguous names (two defs sharing a leaf name) are
skipped rather than guessed.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.engine import (Module, Rule, dotted, own_statements,
                                   stmt_calls)

VIEW = "view-local"
PHYS = "physical"

#: grant APIs: whatever they return is what requests hold (view ids);
#: cow_grant is a one-page grant from the request's own pool/view
VIEW_CALLS = {"_alloc", "_alloc_local", "_new_ids", "cow_grant"}
#: translation / physical-side APIs: results are physical ids;
#: cache_donate converts view ids to physical as ownership moves to the
#: prefix cache
PHYS_CALLS = {"to_physical", "to_physical_local", "_phys", "_phys_local",
              "reclaim", "_take", "cache_donate"}
#: remap tables: indexing or popping one yields a physical id
REMAP_NAMES = {"_remap", "_remap_local"}
#: request attributes that hold view-local ids
REQ_ID_ATTRS = ("pages", "local_pages")
#: attributes that hold cache-owned PHYSICAL ids (prefix cache): reading
#: one taints physical; writing view ids into one is a sink
PHYS_ATTRS = ("shared_pages", "phys_pages")
#: physical-side free lists: extending one with view ids corrupts the pool
PHYS_FREE_NAMES = {"free_local"}

#: pass-through wrappers: taint flows through the first argument
TRANSPARENT_CALLS = {"list", "sorted", "reversed", "tuple", "asarray",
                     "array"}


#: leaf names that must never be shadowed by summaries: the built-in
#: provenance/sink vocabulary always wins, and the pool-accounting
#: verbs are POLYMORPHIC (PoolView overrides them to translate through
#: its remap) -- summarizing one class's body as the behavior of every
#: ``self._dealloc*`` dispatch would indict correct callers
_KNOWN_NAMES = (VIEW_CALLS | PHYS_CALLS | TRANSPARENT_CALLS
                | {"page_table", "_give", "extend", "pop",
                   "_dealloc", "_dealloc_local", "release", "reclaim",
                   "regrant", "grow", "try_admit"})

#: summary fixpoint bound (helper-chain depth the analysis follows)
_MAX_ROUNDS = 4


def _leaf(path: Optional[str]) -> Optional[str]:
    return None if path is None else path.rsplit(".", 1)[-1]


@dataclass
class _FnSummary:
    """Interprocedural facts about one locally defined function."""

    params: List[str]
    #: VIEW / PHYS when every valued return carries that taint;
    #: ("param", i) when the function passes parameter i through
    returns: Optional[object] = None
    #: parameter indices that reach a physical sink (a VIEW argument at
    #: the call site is the caller's bug)
    flags_view: frozenset = field(default_factory=frozenset)
    #: parameter indices translated through to_physical* inside (a PHYS
    #: argument is a double translation)
    flags_phys: frozenset = field(default_factory=frozenset)

    def call_arg(self, call: ast.Call, idx: int) -> Optional[ast.AST]:
        """The call-site expression bound to parameter ``idx``:
        attribute calls (``self._helper(x)``) skip an explicit
        self/cls first parameter; keywords match by parameter name."""
        if not (0 <= idx < len(self.params)):
            return None
        pos = idx
        if (isinstance(call.func, ast.Attribute)
                and self.params[0] in ("self", "cls")):
            pos -= 1
        if 0 <= pos < len(call.args):
            return call.args[pos]
        for kw in call.keywords:
            if kw.arg == self.params[idx]:
                return kw.value
        return None


class PageIdProvenance(Rule):
    rule_id = "ZL001"
    title = "view-local vs physical page-id provenance"

    def __init__(self):
        self._sum: Dict[str, _FnSummary] = {}

    # -- expression taint ---------------------------------------------------
    def _taint(self, node: ast.AST, env: Dict[str, str]) -> Optional[str]:
        if isinstance(node, (ast.Name, ast.Attribute)):
            d = dotted(node)
            if d is None:
                return None
            if d in env:
                return env[d]
            if _leaf(d) in PHYS_ATTRS and "." in d:
                return PHYS
            if _leaf(d) in REQ_ID_ATTRS and "." in d:
                return VIEW
            return None
        if isinstance(node, ast.Call):
            leaf = _leaf(dotted(node.func))
            if leaf in PHYS_CALLS:
                return PHYS
            if leaf in VIEW_CALLS:
                return VIEW
            if leaf == "pop":
                base = _leaf(dotted(getattr(node.func, "value", None)))
                if base in REMAP_NAMES:
                    return PHYS
            if leaf in TRANSPARENT_CALLS and node.args:
                return self._taint(node.args[0], env)
            s = self._sum.get(leaf)
            if s is not None:
                r = s.returns
                if isinstance(r, tuple) and r and r[0] == "param":
                    arg = s.call_arg(node, r[1])
                    return None if arg is None else self._taint(arg, env)
                if r in (VIEW, PHYS):
                    return r
            return None
        if isinstance(node, ast.Subscript):
            base = _leaf(dotted(node.value))
            if base in REMAP_NAMES:
                return PHYS
            return self._taint(node.value, env)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            inner = dict(env)
            for gen in node.generators:
                if isinstance(gen.target, ast.Name):
                    t = self._taint(gen.iter, env)
                    if t is not None:
                        inner[gen.target.id] = t
            return self._taint(node.elt, inner)
        if isinstance(node, ast.BinOp):
            return (self._taint(node.left, env)
                    or self._taint(node.right, env))
        if isinstance(node, ast.IfExp):
            a = self._taint(node.body, env)
            b = self._taint(node.orelse, env)
            return a if a == b else (a or b)
        if isinstance(node, ast.Starred):
            return self._taint(node.value, env)
        return None

    # -- sinks --------------------------------------------------------------
    def _check_call(self, call: ast.Call,
                    env: Dict[str, str]) -> Iterator[Tuple[int, str]]:
        leaf = _leaf(dotted(call.func))
        if leaf == "page_table":
            for kw in call.keywords:
                if kw.arg == "pages" and self._taint(kw.value, env) == VIEW:
                    yield (kw.value.lineno,
                           "view-local page ids reach page_table(pages=...):"
                           " the kernel indexes the device arrays by "
                           "PHYSICAL ids -- translate via "
                           "pool.to_physical() first")
        elif leaf == "_give":
            for arg in call.args:
                if self._taint(arg, env) == VIEW:
                    yield (arg.lineno,
                           "view-local ids returned to the shared pool's "
                           "physical free list (_give): translate via the "
                           "remap before freeing")
        elif leaf in ("to_physical", "to_physical_local",
                      "_phys", "_phys_local"):
            for arg in call.args:
                if self._taint(arg, env) == PHYS:
                    yield (arg.lineno,
                           f"already-physical ids translated again through "
                           f"{leaf}(): double translation resolves through "
                           "the wrong view's remap")
        elif leaf == "extend":
            base = dotted(getattr(call.func, "value", None))
            if (_leaf(base) in PHYS_FREE_NAMES and call.args
                    and self._taint(call.args[0], env) == VIEW):
                yield (call.lineno,
                       "view-local ids pushed onto a physical free list "
                       f"({base}.extend): free the PHYSICAL ids instead")
            if (base is not None and _leaf(base) in REQ_ID_ATTRS
                    and "." in base and call.args
                    and self._taint(call.args[0], env) == PHYS):
                yield (call.lineno,
                       f"physical ids appended to {base}: requests must "
                       "hold view-local ids only (grants already return "
                       "them)")
            if (base is not None and _leaf(base) in PHYS_ATTRS
                    and "." in base and call.args
                    and self._taint(call.args[0], env) == VIEW):
                yield (call.lineno,
                       f"view-local ids appended to {base}: the prefix "
                       "cache holds PHYSICAL ids only -- convert via "
                       "cache_donate()/to_physical() first")
        else:
            s = self._sum.get(leaf)
            if s is None:
                return
            for i in sorted(s.flags_view):
                arg = s.call_arg(call, i)
                if arg is not None and self._taint(arg, env) == VIEW:
                    yield (arg.lineno,
                           f"view-local ids passed to {leaf}() parameter "
                           f"{s.params[i]!r}, which {leaf}() forwards to "
                           "a physical sink: translate via "
                           "pool.to_physical() first")
            for i in sorted(s.flags_phys):
                arg = s.call_arg(call, i)
                if arg is not None and self._taint(arg, env) == PHYS:
                    yield (arg.lineno,
                           f"already-physical ids passed to {leaf}() "
                           f"parameter {s.params[i]!r}, which {leaf}() "
                           "translates again: double translation "
                           "resolves through the wrong view's remap")

    # -- per-function flow (shared by the driver and the summarizer) --------
    def _flow(self, func, env0: Dict[str, str], stmts=None):
        """One tainted walk of ``func`` under initial bindings ``env0``.
        Returns ``(findings, returns)`` where ``returns`` pairs each of
        the function's OWN valued return expressions with its taint."""
        findings: List[Tuple[int, str]] = []
        rets: List[Tuple[ast.AST, Optional[str]]] = []
        own = {id(s) for s in own_statements(func.node)}
        env: Dict[str, str] = dict(env0)
        for stmt in (func.statements() if stmts is None else stmts):
            # sinks first: the env of a statement is everything bound
            # strictly before it
            for call in stmt_calls(stmt):
                findings.extend(self._check_call(call, env))
            if (isinstance(stmt, ast.Return) and id(stmt) in own
                    and stmt.value is not None):
                rets.append((stmt.value, self._taint(stmt.value, env)))
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
                if (len(targets) == 1
                        and isinstance(targets[0], (ast.Tuple, ast.List))
                        and isinstance(stmt.value,
                                       (ast.Tuple, ast.List))
                        and len(targets[0].elts)
                        == len(stmt.value.elts)):
                    pairs = zip(targets[0].elts, stmt.value.elts)
                elif len(targets) == 1:
                    pairs = [(targets[0], stmt.value)]
                else:
                    pairs = [(t, stmt.value) for t in targets]
                for tgt, val in pairs:
                    d = dotted(tgt)
                    if d is None:
                        continue
                    t = self._taint(val, env)
                    if (_leaf(d) in REQ_ID_ATTRS and "." in d
                            and t == PHYS):
                        findings.append(
                            (stmt.lineno,
                             f"physical ids stored on {d}: requests "
                             "must hold view-local ids (the remap is "
                             "the isolation boundary)"))
                    if (_leaf(d) in PHYS_ATTRS and "." in d
                            and t == VIEW):
                        findings.append(
                            (stmt.lineno,
                             f"view-local ids stored on {d}: the "
                             "prefix cache's pages are PHYSICAL -- "
                             "a view id here reads another tenant's "
                             "pages when ids alias"))
                    if t is None:
                        env.pop(d, None)
                    else:
                        env[d] = t
            elif isinstance(stmt, ast.For):
                if isinstance(stmt.target, ast.Name):
                    t = self._taint(stmt.iter, env)
                    if t is not None:
                        env[stmt.target.id] = t
        return findings, rets

    # -- interprocedural summaries ------------------------------------------
    def _summarize(self, mod: Module) -> None:
        """Fixpoint over the module's unambiguously named functions:
        each round re-derives every summary under the previous round's
        summaries, so taint follows helper chains."""
        byname: Dict[str, List] = {}
        for f in mod.functions():
            byname.setdefault(f.name, []).append(f)
        cands = {n: fs[0] for n, fs in byname.items()
                 if len(fs) == 1 and n not in _KNOWN_NAMES}
        stmt_cache = {n: f.statements() for n, f in cands.items()}
        self._sum = {}
        for _ in range(_MAX_ROUNDS):
            new: Dict[str, _FnSummary] = {}
            for name, func in cands.items():
                a = func.node.args
                params = [p.arg for p in a.posonlyargs + a.args]
                stmts = stmt_cache[name]
                base, rets = self._flow(func, {}, stmts)
                fv, fp = set(), set()
                for i, p in enumerate(params):
                    if len(self._flow(func, {p: VIEW}, stmts)[0]) \
                            > len(base):
                        fv.add(i)
                    if len(self._flow(func, {p: PHYS}, stmts)[0]) \
                            > len(base):
                        fp.add(i)
                taints = {t for _, t in rets}
                ret = None
                if rets and None not in taints and len(taints) == 1:
                    ret = next(iter(taints))
                elif (rets and taints == {None}
                      and all(isinstance(e, ast.Name) for e, _ in rets)
                      and len({e.id for e, _ in rets}) == 1
                      and rets[0][0].id in params):
                    ret = ("param", params.index(rets[0][0].id))
                new[name] = _FnSummary(params=params, returns=ret,
                                       flags_view=frozenset(fv),
                                       flags_phys=frozenset(fp))
            if new == self._sum:
                break
            self._sum = new

    # -- driver -------------------------------------------------------------
    def run(self, mod: Module) -> Iterator[Tuple[int, str]]:
        self._summarize(mod)
        for func in mod.functions():
            yield from self._flow(func, {})[0]
