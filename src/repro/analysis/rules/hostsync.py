"""ZL004: host synchronization inside serving hot paths.

A paged decode step is asynchronous end to end: the engine enqueues
device work and the only host<->device round trip is the one batched
token fetch per step.  Any *extra* sync in the per-token path --
``.item()``, ``int()``/``float()`` on a device array, ``np.asarray`` on
a jit result, ``jax.device_get``, an implicit bool coercion -- stalls
the device pipeline once per token per request and quietly multiplies
TTFT.  Worse, inside the jit-traced ``_fn`` bodies the same calls are
correctness bugs (a tracer has no concrete value to sync).

This rule tracks which names in a hot-path function hold device values
(results of module-registered jitted callables or of ``jnp.*`` calls)
and flags every host-forcing operation on them, plus the operations
that always sync regardless of operand (``.item()``,
``jax.device_get``).  The deliberate one-sync-per-step sites carry a
``# zenlint: ignore[ZL004]`` with their justification -- the rule's job
is making every OTHER sync a conscious, reviewed decision.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional, Set, Tuple

from repro.analysis.engine import Module, Rule, dotted, stmt_exprs
from repro.analysis.rules.common import assigned_names, is_hot_path

COERCIONS = {"int", "float", "bool", "complex"}


def _leaf(path: Optional[str]) -> Optional[str]:
    return None if path is None else path.rsplit(".", 1)[-1]


def _is_device_call(call: ast.Call, jitted) -> bool:
    d = dotted(call.func)
    if d is None:
        return False
    if d.split(".", 1)[0] == "jnp":
        return True
    return d.rsplit(".", 1)[-1] in jitted


def _mentions_device(node: ast.AST, device: Set[str], jitted) -> bool:
    for n in ast.walk(node):
        if isinstance(n, (ast.Name, ast.Attribute)):
            d = dotted(n)
            if d is not None and d in device:
                return True
        elif isinstance(n, ast.Call) and _is_device_call(n, jitted):
            return True
    return False


class HostSyncInHotPath(Rule):
    rule_id = "ZL004"
    title = "host synchronization in serving hot paths"

    def run(self, mod: Module) -> Iterator[Tuple[int, str]]:
        jitted = mod.jit_bindings()
        for func in mod.functions():
            if not is_hot_path(func):
                continue
            device: Set[str] = set()
            for stmt in func.statements():
                for expr in stmt_exprs(stmt):
                    yield from self._check_expr(expr, device, jitted)
                if isinstance(stmt, ast.If):
                    if _mentions_device(stmt.test, device, jitted):
                        yield (stmt.lineno,
                               "implicit bool() of a device value in an "
                               "if-test: this blocks on the device -- "
                               "restructure, or sync once explicitly")
                # update the device-name set AFTER checking: assignment
                # from a jit/jnp call marks the targets device-resident,
                # anything else (np.asarray(...), literals) clears them
                if isinstance(stmt, ast.Assign):
                    is_dev = _mentions_device(stmt.value, device, jitted)
                    if (isinstance(stmt.value, ast.Call)
                            and dotted(stmt.value.func)
                            in ("np.asarray", "np.array", "numpy.asarray",
                                "numpy.array", "jax.device_get")):
                        # the flagged sync itself lands the value on host:
                        # downstream reads of the target are sync-free
                        is_dev = False
                    for t in stmt.targets:
                        for path in assigned_names(t):
                            if is_dev:
                                device.add(path)
                            else:
                                device.discard(path)

    def _check_expr(self, expr: ast.AST, device: Set[str],
                    jitted) -> Iterator[Tuple[int, str]]:
        for call in (n for n in ast.walk(expr)
                     if isinstance(n, ast.Call)):
            cd = dotted(call.func)
            leaf = _leaf(cd)
            if leaf == "item" and isinstance(call.func, ast.Attribute):
                yield (call.lineno,
                       ".item() in a hot path: one blocking device->host "
                       "transfer per call -- batch the fetch per step")
            elif cd == "jax.device_get":
                yield (call.lineno,
                       "jax.device_get in a hot path: blocking transfer "
                       "-- batch the fetch per step")
            elif (cd in ("np.asarray", "np.array", "numpy.asarray",
                         "numpy.array") and call.args
                  and _mentions_device(call.args[0], device, jitted)):
                yield (call.lineno,
                       f"{cd} on a device value in a hot path: blocking "
                       "device->host transfer -- keep the value on device "
                       "or batch the fetch")
            elif (isinstance(call.func, ast.Name)
                  and call.func.id in COERCIONS and call.args
                  and _mentions_device(call.args[0], device, jitted)):
                yield (call.lineno,
                       f"{call.func.id}() on a device value in a hot "
                       "path: implicit blocking sync -- fetch the batch "
                       "once (np.asarray after the step) and index that")
