"""ZL005: pool-accounting pairing -- reclaim receipts must be consumed.

``reclaim``/``drain``/``park``/``regrant`` are the four verbs that move
pages and bytes between an application and the pod, and every one of
them RETURNS the evidence of what moved: reclaim/drain hand back the
physical ids whose contents must be snapshotted before co-tenants reuse
them, ``scheduler.park`` returns the freed bytes unpark must reacquire,
and ``regrant`` returns whether the pages came back at all.  Dropping
any of these on the floor is how pages get stranded and parked apps
become unresumable -- silently, because the accounting still "adds up"
until the next unpark.

The prefix cache adds three more receipt verbs: ``pin`` returns the
match (pinned node chain + physical pages) that MUST later be unpinned,
``unpin`` returns how many refcounts hit zero (the eviction-eligibility
signal the caller folds into stats), and ``cow_grant`` returns the
granted copy-target page or ``None`` -- ignoring it either leaks the
page or dereferences a failed grant.

This rule flags, per function:

* a receipt-bearing call used as a bare expression statement (the
  result is discarded outright);
* a receipt bound to a name that is never read afterwards;
* an early ``return`` between binding a receipt and its first use --
  the exit path walks off with pages reclaimed but their receipt
  unconsumed (the "all exits" half of the invariant, approximated
  lexically).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Tuple

from repro.analysis.engine import Module, Rule, dotted, stmt_exprs

RECEIPT_CALLS = {"reclaim", "drain", "park", "regrant",
                 "pin", "unpin", "cow_grant"}


def _leaf(path: Optional[str]) -> Optional[str]:
    return None if path is None else path.rsplit(".", 1)[-1]


def _receipt_call(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Call):
        leaf = _leaf(dotted(node.func))
        if leaf in RECEIPT_CALLS:
            return leaf
    return None


def _reads_name(node: ast.AST, name: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == name
               and isinstance(n.ctx, ast.Load)
               for n in ast.walk(node))


class AccountingPairing(Rule):
    rule_id = "ZL005"
    title = "reclaim/park receipts must be consumed on every path"

    def run(self, mod: Module) -> Iterator[Tuple[int, str]]:
        for func in mod.functions():
            # name -> (verb, bind line), pending first use
            pending: Dict[str, Tuple[str, int]] = {}
            for stmt in func.statements():
                # discarded outright: `pool.reclaim(req)` as a statement
                if isinstance(stmt, ast.Expr):
                    verb = _receipt_call(stmt.value)
                    if verb is not None:
                        yield (stmt.lineno,
                               f"result of {verb}() discarded: the receipt "
                               "(page ids / freed bytes / success flag) is "
                               "the only record of what moved -- consume "
                               "or propagate it")
                # early exit with receipts still unconsumed
                if isinstance(stmt, ast.Return):
                    returns = stmt.value
                    for name, (verb, line) in list(pending.items()):
                        if returns is not None and _reads_name(returns,
                                                               name):
                            pending.pop(name)
                        else:
                            yield (stmt.lineno,
                                   f"return before '{name}' (the {verb}() "
                                   f"receipt bound at line {line}) is "
                                   "consumed: this exit strands the "
                                   "reclaimed pages/bytes")
                    continue
                # any read of a pending name counts as consumption
                for expr in stmt_exprs(stmt):
                    for used in [n for n in pending
                                 if _reads_name(expr, n)]:
                        pending.pop(used)
                # new receipt bindings (checked last: the binding
                # statement's own value is the call, not a consumption)
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    tgt = stmt.targets[0]
                    verb = None
                    for n in ast.walk(stmt.value):
                        verb = verb or _receipt_call(n)
                    if verb is not None and isinstance(tgt, ast.Name):
                        pending[tgt.id] = (verb, stmt.lineno)
            for name, (verb, line) in pending.items():
                yield (line,
                       f"'{name}' holds a {verb}() receipt that is never "
                       "consumed in this function -- the reclaimed "
                       "pages/bytes have no paired regrant/release/"
                       "snapshot handling")
