"""ZL005: pool-accounting pairing -- reclaim receipts must be consumed.

``reclaim``/``drain``/``park``/``regrant`` are the four verbs that move
pages and bytes between an application and the pod, and every one of
them RETURNS the evidence of what moved: reclaim/drain hand back the
physical ids whose contents must be snapshotted before co-tenants reuse
them, ``scheduler.park`` returns the freed bytes unpark must reacquire,
and ``regrant`` returns whether the pages came back at all.  Dropping
any of these on the floor is how pages get stranded and parked apps
become unresumable -- silently, because the accounting still "adds up"
until the next unpark.

The prefix cache adds three more receipt verbs: ``pin`` returns the
match (pinned node chain + physical pages) that MUST later be unpinned,
``unpin`` returns how many refcounts hit zero (the eviction-eligibility
signal the caller folds into stats), and ``cow_grant`` returns the
granted copy-target page or ``None`` -- ignoring it either leaks the
page or dereferences a failed grant.

This rule flags, per function:

* a receipt-bearing call used as a bare expression statement (the
  result is discarded outright);
* a receipt bound to a name that is never read afterwards;
* an early ``return`` between binding a receipt and its first use --
  the exit path walks off with pages reclaimed but their receipt
  unconsumed (the "all exits" half of the invariant, approximated
  lexically).

**Interprocedural flow**: a helper that merely relays a receipt --
``def _park_all(pool, req): return pool.reclaim(req)`` -- launders the
verb name away, so a caller discarding ``_park_all(...)`` drops the
same pages the inline version would.  The rule therefore widens the
verb set per module: a locally defined, unambiguously named function
whose every valued ``return`` is a receipt call (directly, or a bare
name bound straight from one) is itself receipt-bearing for its
callers.  The criterion is deliberately strict -- a function returning
a dict that merely *contains* a receipt (``parking.park_app``) keeps
custody of it and is NOT widened -- and iterates to a fixpoint so
chains of relays are followed.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set, Tuple

from repro.analysis.engine import (Module, Rule, dotted, own_statements,
                                   stmt_exprs)

RECEIPT_CALLS = {"reclaim", "drain", "park", "regrant",
                 "pin", "unpin", "cow_grant"}

#: fixpoint bound for relay chains (helper returning a helper's receipt)
_MAX_ROUNDS = 4


def _leaf(path: Optional[str]) -> Optional[str]:
    return None if path is None else path.rsplit(".", 1)[-1]


def _receipt_call(node: ast.AST, verbs: Set[str]) -> Optional[str]:
    if isinstance(node, ast.Call):
        leaf = _leaf(dotted(node.func))
        if leaf in verbs:
            return leaf
    return None


def _reads_name(node: ast.AST, name: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == name
               and isinstance(n.ctx, ast.Load)
               for n in ast.walk(node))


class AccountingPairing(Rule):
    rule_id = "ZL005"
    title = "reclaim/park receipts must be consumed on every path"

    def _verbs(self, mod: Module) -> Set[str]:
        """RECEIPT_CALLS widened with the module's receipt-relaying
        functions (every valued return IS a receipt, see module doc)."""
        byname: Dict[str, list] = {}
        for f in mod.functions():
            byname.setdefault(f.name, []).append(f)
        cands = {n: fs[0] for n, fs in byname.items()
                 if len(fs) == 1 and n not in RECEIPT_CALLS}
        verbs = set(RECEIPT_CALLS)
        for _ in range(_MAX_ROUNDS):
            grown = False
            for name, func in cands.items():
                if name in verbs:
                    continue
                relayed: Dict[str, bool] = {}
                valued, all_receipts = 0, True
                for stmt in own_statements(func.node):
                    if (isinstance(stmt, ast.Return)
                            and stmt.value is not None):
                        valued += 1
                        v = stmt.value
                        if not (_receipt_call(v, verbs) is not None
                                or (isinstance(v, ast.Name)
                                    and relayed.get(v.id, False))):
                            all_receipts = False
                        continue
                    # any intermediate read means the helper consumed
                    # the receipt itself (e.g. folding a count into
                    # stats) -- its return value is informational, not
                    # a relayed receipt
                    for expr in stmt_exprs(stmt):
                        for name in [n for n, ok in relayed.items()
                                     if ok and _reads_name(expr, n)]:
                            relayed[name] = False
                    if (isinstance(stmt, ast.Assign)
                            and len(stmt.targets) == 1
                            and isinstance(stmt.targets[0], ast.Name)):
                        relayed[stmt.targets[0].id] = (
                            _receipt_call(stmt.value, verbs) is not None)
                if valued and all_receipts:
                    verbs.add(name)
                    grown = True
            if not grown:
                break
        return verbs

    def run(self, mod: Module) -> Iterator[Tuple[int, str]]:
        verbs = self._verbs(mod)
        for func in mod.functions():
            # name -> (verb, bind line), pending first use
            pending: Dict[str, Tuple[str, int]] = {}
            for stmt in func.statements():
                # discarded outright: `pool.reclaim(req)` as a statement
                if isinstance(stmt, ast.Expr):
                    verb = _receipt_call(stmt.value, verbs)
                    if verb is not None:
                        yield (stmt.lineno,
                               f"result of {verb}() discarded: the receipt "
                               "(page ids / freed bytes / success flag) is "
                               "the only record of what moved -- consume "
                               "or propagate it")
                # early exit with receipts still unconsumed
                if isinstance(stmt, ast.Return):
                    returns = stmt.value
                    for name, (verb, line) in list(pending.items()):
                        if returns is not None and _reads_name(returns,
                                                               name):
                            pending.pop(name)
                        else:
                            yield (stmt.lineno,
                                   f"return before '{name}' (the {verb}() "
                                   f"receipt bound at line {line}) is "
                                   "consumed: this exit strands the "
                                   "reclaimed pages/bytes")
                    continue
                # any read of a pending name counts as consumption
                for expr in stmt_exprs(stmt):
                    for used in [n for n in pending
                                 if _reads_name(expr, n)]:
                        pending.pop(used)
                # new receipt bindings (checked last: the binding
                # statement's own value is the call, not a consumption)
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    tgt = stmt.targets[0]
                    verb = None
                    for n in ast.walk(stmt.value):
                        verb = verb or _receipt_call(n, verbs)
                    if verb is not None and isinstance(tgt, ast.Name):
                        pending[tgt.id] = (verb, stmt.lineno)
            for name, (verb, line) in pending.items():
                yield (line,
                       f"'{name}' holds a {verb}() receipt that is never "
                       "consumed in this function -- the reclaimed "
                       "pages/bytes have no paired regrant/release/"
                       "snapshot handling")
