"""ZL003: recompile hazards -- per-request values becoming compile keys.

Bursty serving stays O(1)-compile (PR 4's property) only because every
value that reaches a jit compile key is *bucketed* first: batch padded
to ``max_batch``, page-table width to the next power of two, prompts to
whole pages.  One careless edit -- a raw ``req.prompt_len`` as a static
arg, a staging array shaped by ``len(running)`` -- and the engine
recompiles per request under load, which is exactly the pathology the
``decode_traces``/``prefill_traces`` counters were added to catch *at
runtime*.  This rule catches it at lint time instead.  In hot-path
functions (see :mod:`repro.analysis.rules.common`) it flags:

* ``jax.jit(...)`` constructed inside the hot path itself -- a fresh jit
  wrapper never hits the trace cache, so this retraces every call;
* a per-request, non-bucketed expression passed at a ``static_argnums``
  / ``static_argnames`` position of a module-registered jitted callable;
* a per-request, non-bucketed expression inside the shape argument of a
  host-side staging-array constructor (``np.zeros``/``ones``/``full``/
  ``empty``) -- those arrays' shapes feed straight into the jit compile
  key of the call they are staged for.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional, Tuple

from repro.analysis.engine import (Module, Rule, dotted, parse_jit_call,
                                   stmt_calls)
from repro.analysis.rules.common import (classify_env, is_bucketed,
                                         is_hot_path, is_request_derived)

STAGING_CONSTRUCTORS = {"zeros", "ones", "full", "empty"}


def _leaf(path: Optional[str]) -> Optional[str]:
    return None if path is None else path.rsplit(".", 1)[-1]


class RecompileHazard(Rule):
    rule_id = "ZL003"
    title = "per-request values reaching jit compile keys in hot paths"

    def run(self, mod: Module) -> Iterator[Tuple[int, str]]:
        jitted = mod.jit_bindings()
        for func in mod.functions():
            if not is_hot_path(func):
                continue
            env = classify_env(func)
            for stmt in func.statements():
                for call in stmt_calls(stmt):
                    yield from self._check_call(call, env, jitted)

    def _check_call(self, call: ast.Call, env, jitted):
        leaf = _leaf(dotted(call.func))
        if parse_jit_call(call) is not None:
            yield (call.lineno,
                   "jax.jit constructed inside a hot path: a fresh jit "
                   "wrapper retraces on every call -- build it once in "
                   "__init__ and call the bound version here")
            return
        if leaf in STAGING_CONSTRUCTORS and call.args:
            shape = call.args[0]
            if (is_request_derived(shape, env)
                    and not is_bucketed(shape, env)):
                yield (shape.lineno,
                       f"per-request value in the shape of a staging "
                       f"{leaf}(): this shape becomes a jit compile key "
                       "-- bucket it (max_batch padding, _next_pow2, "
                       "page math) first")
            return
        info = jitted.get(leaf) if leaf else None
        if info is None:
            return
        # a per-request-SHAPED array as a traced argument recompiles just
        # as surely as a static one: the shape is part of the compile
        # key.  Bare names only -- inline wrappers like
        # ``jnp.asarray(req.prompt_len - 1)`` are scalars, and scalar
        # builtins (max/len/...) are exempted by classify_env.
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            for n in ast.walk(arg):
                if (isinstance(n, ast.Name)
                        and env.get(n.id) == "request"):
                    yield (call.lineno,
                           f"'{n.id}' is a per-request-shaped value "
                           f"passed to jitted {leaf}(): its shape is a "
                           "compile key -- bucket it (pad to a page/"
                           "power-of-two boundary) first")
        if not (info.static or info.static_names):
            return
        hazards = []
        for idx in info.static:
            if idx < len(call.args):
                hazards.append(call.args[idx])
        for kw in call.keywords:
            if kw.arg in info.static_names:
                hazards.append(kw.value)
        for arg in hazards:
            if is_request_derived(arg, env) and not is_bucketed(arg, env):
                yield (arg.lineno,
                       f"per-request value at a static_argnums position "
                       f"of {leaf}(): every distinct value is a fresh "
                       "XLA compile -- bucket it or make it a traced "
                       "argument")
