"""zenlint: AST invariant analysis for the Zenix serving data plane.

The repo's hardest invariants are conventions the type system cannot
see -- view-local vs physical page ids, donated jit buffers, O(1)-compile
bucketing, sync-free hot paths, reclaim/regrant pairing.  This package
machine-checks them at lint time:

    PYTHONPATH=src python -m repro.analysis src benchmarks examples

Programmatic surface: :func:`analyze_paths` / :func:`analyze_source`
return :class:`Finding` lists; ``rules.ALL_RULES`` is the registry.
Suppress a single finding with an inline justification::

    risky_line()   # zenlint: ignore[ZL004] -- why this one is fine

Runs on the standard library only (no jax import), so it works in any
CI job.
"""

from repro.analysis.engine import (Finding, Module, Rule, analyze_paths,
                                   analyze_source)

__all__ = ["Finding", "Module", "Rule", "analyze_paths", "analyze_source"]
