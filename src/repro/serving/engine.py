"""Continuous-batching serving engine.

The serving-side runtime of the framework: admits requests against the
page pool (sizing policy from history), runs prefill for new requests and
batched decode for running ones, grows KV grants on demand, and preempts
the newest request when the pool is exhausted (re-queued: the paper's
at-least-once component re-execution).

The engine is deliberately execution-backend-agnostic: ``step_fns`` carry
(prefill, decode) callables so tests can run a real tiny model while the
scheduler benchmarks drive a null executor."""

from __future__ import annotations

import collections
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.history import HistoryStore
from repro.serving.kv_cache import PAGE_SIZE, PagePool, Request


@dataclass
class EngineStats:
    admitted: int = 0
    completed: int = 0
    preempted: int = 0
    decode_steps: int = 0
    prefills: int = 0
    tokens_generated: int = 0
    wall_s: float = 0.0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class ServingEngine:
    def __init__(self, pool: PagePool, max_batch: int = 8,
                 step_fns: Optional[Tuple[Callable, Callable]] = None,
                 history: Optional[HistoryStore] = None):
        self.pool = pool
        self.max_batch = max_batch
        self.queue: Deque[Request] = collections.deque()
        self.running: List[Request] = []
        self.stats = EngineStats()
        self.step_fns = step_fns
        self.history = history

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> List[Request]:
        admitted = []
        while self.queue and len(self.running) < self.max_batch:
            req = self.queue[0]
            if not self.pool.try_admit(req):
                break
            self.queue.popleft()
            self.running.append(req)
            admitted.append(req)
            self.stats.admitted += 1
        return admitted

    def _preempt_newest(self) -> None:
        if not self.running:
            return
        victim = max(self.running, key=lambda r: -r.generated)
        self.running.remove(victim)
        self.pool.release(victim)
        victim.state = "queued"
        victim.generated = 0          # re-execute (at-least-once)
        self.queue.appendleft(victim)
        self.stats.preempted += 1

    def step(self) -> bool:
        """One engine iteration.  Returns False when fully drained."""
        newly = self._admit()
        if self.step_fns is not None:
            prefill_fn, decode_fn = self.step_fns
            for req in newly:
                prefill_fn(req)
                self.stats.prefills += 1
        else:
            self.stats.prefills += len(newly)

        if not self.running:
            return bool(self.queue)

        # grow grants before decoding; preempt if the pool is exhausted
        for req in list(self.running):
            if not self.pool.grow(req):
                self._preempt_newest()

        if self.step_fns is not None:
            _, decode_fn = self.step_fns
            decode_fn(self.running)
        for req in list(self.running):
            req.generated += 1
            self.stats.tokens_generated += 1
            if req.generated >= req.max_new_tokens:
                self.running.remove(req)
                self.pool.release(req)
                self.stats.completed += 1
        self.stats.decode_steps += 1
        return bool(self.queue or self.running)

    def run_to_completion(self, max_steps: int = 1_000_000) -> EngineStats:
        t0 = time.perf_counter()
        steps = 0
        while self.step():
            steps += 1
            if steps >= max_steps:
                break
        self.stats.wall_s = time.perf_counter() - t0
        return self.stats
