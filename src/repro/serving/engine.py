"""Continuous-batching serving engine.

The serving-side runtime of the framework: admits requests against the
page pool (sizing policy from history), runs prefill for new requests and
batched decode for running ones, grows KV grants on demand, and preempts
under pool pressure (re-queued: the paper's at-least-once component
re-execution).

The engine is execution-backend-agnostic: model execution is carried by a
:class:`~repro.serving.model_runner.ModelRunner` (``runner=``) or a raw
``step_fns`` (prefill, decode) pair, so tests can run a real tiny model
while the scheduler benchmarks drive a null executor with neither.

Multi-tenancy: the ``pool`` may be a private
:class:`~repro.serving.kv_cache.PagePool` or a
:class:`~repro.serving.tenancy.PoolView` onto a pod-shared pool (where
requests carry view-local page ids and same-KV-shape tenants alias one
physical device array set).  Under pressure the engine first asks the
pool to arbitrate (``preempt_any`` -- cross-app fair-share preemption,
which with aliasing moves *physical* pages between apps), falling back
to preempting its own newest request."""

from __future__ import annotations

import collections
import dataclasses
import time
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.analysis import zensan
from repro.core.history import HistoryStore
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving.kv_cache import PagePool, Request


@dataclass
class EngineStats:
    admitted: int = 0
    completed: int = 0
    rejected: int = 0                  # could never fit pool/quota cap
    preempted: int = 0
    decode_steps: int = 0
    prefills: int = 0
    tokens_generated: int = 0
    wall_s: float = 0.0
    # per-request latency signals (the autoscaling inputs)
    ttft_s_sum: float = 0.0            # submit -> first token, summed
    ttft_count: int = 0
    decode_s_sum: float = 0.0          # summed decode-step wall time

    # every field except wall_s is a monotonic counter; wall_s is a gauge
    # (overwritten per run_to_completion), so deltas exclude it
    COUNTERS = ("admitted", "completed", "rejected", "preempted",
                "decode_steps", "prefills", "tokens_generated",
                "ttft_s_sum", "ttft_count", "decode_s_sum")

    @property
    def mean_ttft_s(self) -> float:
        return self.ttft_s_sum / max(self.ttft_count, 1)

    @property
    def mean_decode_step_s(self) -> float:
        return self.decode_s_sum / max(self.decode_steps, 1)

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["mean_ttft_s"] = self.mean_ttft_s
        d["mean_decode_step_s"] = self.mean_decode_step_s
        return d

    # -- windowed semantics (autoscaling policies consume rates, not
    # lifetime totals) -------------------------------------------------------
    def snapshot(self) -> "EngineStats":
        """A marker for later ``delta(since=...)`` calls."""
        return dataclasses.replace(self)

    def delta(self, since: "EngineStats") -> "EngineStats":
        """Counters accumulated SINCE a snapshot: the per-window view
        (mean_ttft_s etc. then reflect only that window)."""
        out = dataclasses.replace(self)
        for f in self.COUNTERS:
            setattr(out, f, getattr(self, f) - getattr(since, f))
        return out

    def reset(self) -> "EngineStats":
        """Zero the counters in place, returning the pre-reset snapshot
        (the alternative windowing style: one window per reset)."""
        snap = self.snapshot()
        for f in self.COUNTERS:
            setattr(self, f, type(getattr(self, f))(0))
        return snap


class ServingEngine:
    def __init__(self, pool: PagePool, max_batch: int = 8,
                 step_fns: Optional[Tuple[Callable, Callable]] = None,
                 history: Optional[HistoryStore] = None,
                 runner=None):
        self.pool = pool
        self.max_batch = max_batch
        self.queue: Deque[Request] = collections.deque()
        self.running: List[Request] = []
        self.stats = EngineStats()
        self.runner = runner
        if runner is not None:
            runner.bind(self)
            step_fns = (runner.prefill, runner.decode)
        self.step_fns = step_fns
        self.history = history
        # observability lane label: the tenancy view's app name, or a
        # generic lane for private pools (obs is off unless enabled)
        self._obs_app = getattr(pool, "app", None) or "serve"
        attach = getattr(pool, "attach", None)
        if attach is not None:          # tenancy view: register for cross-app
            attach(self)                # victim selection

    def submit(self, req: Request, *,
               submitted_at: Optional[float] = None) -> None:
        # the router stamps arrival time once at the front door and passes
        # it through, so TTFT includes router-queue wait on dispatch
        req.submitted_at = (time.perf_counter() if submitted_at is None
                            else submitted_at)
        self.queue.append(req)
        t = obs_trace.TRACER
        if t is not None:
            t.instant("request", "submit", req.req_id,
                      {"app": self._obs_app, "prompt_len": req.prompt_len,
                       "max_new_tokens": req.max_new_tokens})

    def _admit(self) -> List[Request]:
        admitted = []
        attach = getattr(self.runner, "prefix_attach", None)
        t = obs_trace.TRACER
        m = obs_metrics.METRICS
        while self.queue and len(self.running) < self.max_batch:
            req = self.queue[0]
            if not self.pool.admissible(req):
                # can NEVER complete under the pool/quota cap: rejecting
                # beats an admit/grow-deny/preempt livelock that would
                # also bleed co-tenants dry
                self.queue.popleft()
                req.state = "rejected"
                self.stats.rejected += 1
                if t is not None:
                    t.instant("request", "reject", req.req_id,
                              {"cause": "inadmissible",
                               "prompt_len": req.prompt_len})
                continue
            if attach is not None:
                # prefix-cache lookup+pin BEFORE the grant: a hit shrinks
                # the private-page need try_admit charges the quota for
                attach(req)
            if not self.pool.try_admit(req):
                # no grant, no pin: a queued request must not hold cache
                # pages against eviction while it waits
                self.pool.prefix_detach(req)
                break
            self.queue.popleft()
            self.running.append(req)
            admitted.append(req)
            self.stats.admitted += 1
            if t is not None or m is not None:
                wait = time.perf_counter() - req.submitted_at
                if t is not None:
                    t.instant("request", "admit", req.req_id,
                              {"queue_wait_s": wait,
                               "prompt_len": req.prompt_len,
                               "batch": len(self.running)})
                if m is not None:
                    m.histogram("repro_queue_wait_seconds",
                                app=self._obs_app).observe(wait)
        return admitted

    def preempt(self, victim: Request) -> None:
        """Release a running request's pages and requeue it for
        re-execution (at-least-once)."""
        self.running.remove(victim)
        self.pool.release(victim)
        victim.state = "queued"
        victim.generated = 0          # re-execute (at-least-once)
        self.queue.appendleft(victim)
        self.stats.preempted += 1
        t = obs_trace.TRACER
        if t is not None:
            t.instant("request", "preempt", victim.req_id,
                      {"app": self._obs_app})

    def preempt_newest(self) -> bool:
        """Preempt the request with the least progress; False when there is
        nothing to preempt."""
        if not self.running:
            return False
        self.preempt(min(self.running, key=lambda r: r.generated))
        return True

    def drain(self) -> List[Tuple[Request, Tuple[List[int], List[int]]]]:
        """Park support: reclaim every running request's pages without
        completing it.  Returns (request, (global page ids, local ring
        page ids)) in running order -- the order matters, because unpark
        must rebuild ``running`` in the same order for batch-identical
        decoding.  The ids are *physical* (``reclaim`` translates a
        tenancy view's view-local ids before freeing them) and the page
        *contents* are untouched; the caller
        (``repro.autoscale.parking``) snapshots them to host before the
        ids are re-allocated -- possibly by an aliased co-tenant."""
        drained = []
        for req in list(self.running):
            drained.append((req, self.pool.reclaim(req)))
        self.running.clear()
        return drained

    def _reclaim(self) -> bool:
        """Free pages under pressure.  A shared-pool view arbitrates across
        every app on the pod (fair-share victim selection); a private pool
        falls back to this engine's own newest request."""
        preempt_any = getattr(self.pool, "preempt_any", None)
        if preempt_any is not None:
            if preempt_any():
                return True
        return self.preempt_newest()

    def step(self) -> bool:
        """One engine iteration.  Returns False when fully drained."""
        t = obs_trace.TRACER
        m = obs_metrics.METRICS
        newly = self._admit()
        if self.step_fns is not None:
            prefill_fn, _ = self.step_fns
            for req in newly:
                tp0 = time.perf_counter() if t is not None else 0.0
                prefill_fn(req)
                self.stats.prefills += 1
                if t is not None:
                    t.span("request", "prefill", tp0, time.perf_counter(),
                           req.req_id, {"prompt_len": req.prompt_len})
        else:
            self.stats.prefills += len(newly)
            if t is not None:
                for req in newly:
                    t.instant("request", "prefill", req.req_id,
                              {"prompt_len": req.prompt_len})
        now = time.perf_counter()
        for req in newly:
            if req.first_token_at is None:   # not a re-admission
                req.first_token_at = now
                ttft = now - req.submitted_at
                self.stats.ttft_s_sum += ttft
                self.stats.ttft_count += 1
                if t is not None:
                    t.instant("request", "first_token", req.req_id,
                              {"ttft_s": ttft})
                if m is not None:
                    m.histogram("repro_ttft_seconds",
                                app=self._obs_app).observe(ttft)

        if not self.running:
            s = zensan.SAN
            if s is not None:
                s.check(self.pool)
            return bool(self.queue)

        # Grow grants before decoding (horizon=1: the next token's write
        # slot must be page-backed); preempt under pool pressure.  The
        # `req in self.running` condition skips requests preempted by an
        # earlier reclaim in this pass -- growing one would grant pages to
        # a request whose pages were just released (page leak).
        for req in list(self.running):
            while req in self.running and not self.pool.grow(req, horizon=1):
                if not self._reclaim():
                    break

        if self.step_fns is not None:
            _, decode_fn = self.step_fns
            t0 = time.perf_counter()
            decode_fn(self.running)
            t1 = time.perf_counter()
            self.stats.decode_s_sum += t1 - t0
            if t is not None:
                t.span("engine", "decode_step", t0, t1, self._obs_app,
                       {"batch": len(self.running),
                        "queue": len(self.queue)})
            if m is not None:
                m.histogram("repro_decode_step_seconds",
                            app=self._obs_app).observe(t1 - t0)
                m.histogram("repro_batch_occupancy",
                            obs_metrics.OCCUPANCY_BOUNDS,
                            app=self._obs_app).observe(len(self.running))
        else:
            # no decode fn: no latency to time, but the occupancy signal
            # (how full continuous batches run) is still real
            if t is not None:
                t.instant("engine", "decode_step", self._obs_app,
                          {"batch": len(self.running),
                           "queue": len(self.queue)})
            if m is not None:
                m.histogram("repro_batch_occupancy",
                            obs_metrics.OCCUPANCY_BOUNDS,
                            app=self._obs_app).observe(len(self.running))
        for req in list(self.running):
            req.generated += 1
            self.stats.tokens_generated += 1
            if req.generated >= req.max_new_tokens:
                self.running.remove(req)
                self.pool.release(req)
                if self.runner is not None:
                    # evict per-request runner state (tokens move to
                    # req.output_tokens): a long-running engine must not
                    # accumulate completed requests' token lists
                    self.runner.finish(req)
                self.stats.completed += 1
                if t is not None:
                    t.instant("request", "finish", req.req_id,
                              {"tokens": req.generated})
        self.stats.decode_steps += 1
        s = zensan.SAN
        if s is not None:
            s.check(self.pool)
        return bool(self.queue or self.running)

    def run_to_completion(self, max_steps: int = 1_000_000) -> EngineStats:
        t0 = time.perf_counter()
        steps = 0
        while self.step():
            steps += 1
            if steps >= max_steps:
                break
        self.stats.wall_s = time.perf_counter() - t0
        return self.stats

    def shutdown(self) -> None:
        """Release every held page and detach from a shared pool (called on
        application release so co-tenants get the pages back)."""
        for req in list(self.running):
            self.pool.release(req)
        self.running.clear()
        self.queue.clear()
        close = getattr(self.pool, "close", None)
        if close is not None:
            close()
