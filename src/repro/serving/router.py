"""Front-end request router + replica sets: the scale-out data plane.

The paper scales an application by adjusting the *resources* behind it,
not by making the user manage instances.  This module adds the two
compute-side scaling dimensions -- replica count and continuous-batch
width -- behind a front door the user never sees past:

* :class:`RequestRouter` -- one per pod (``Cluster.router``).  It owns
  one FIFO queue per application and continuously dispatches queued
  requests across the app's replicas, join-shortest-queue among the
  replicas with batch headroom.  Binding is late: a request waits in
  the router queue (where its depth is the replica-scaling signal)
  until some replica can actually grow its continuous batch, instead
  of being pinned early to a lane that turns out slow.  Fairness across
  tenants is structural -- every app has its own queue and its own
  replicas, and ``step()`` services every app each round, so a heavy
  tenant's backlog cannot head-of-line-block a light one (pool pressure
  is still arbitrated by the shared pool's fair-share preemption).

* :class:`ReplicaSet` -- N :class:`ServingEngine` replicas of ONE app.
  Each replica is its own :class:`PoolView` (named ``app@rN`` past the
  first, all sharing the app's sizing-history series), but all replicas
  share the pod's ``SharedPagePool``, ``KVArrayStore`` device arrays,
  and prefix cache -- and past the first replica the model params are
  aliased, so adding a replica costs *compute slots*, not duplicated
  KV or weights.

Removing a replica reuses the PR-3 park machinery: the victim engine
``drain()``s (pages reclaimed, contents intact on device), the runner
gathers the drained KV (``migrate_out``), the requests re-acquire pages
on a surviving replica's view and the KV scatters back at the new
grants (``migrate_in``) -- token-identical continuation, because every
replica decodes through the same physical array set.  Requests that
don't fit the survivor (batch slots, pages, or a non-migratable dense
cache) fall back to the at-least-once path: requeued at the router,
re-executed from scratch, still deterministic.
"""

from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

from repro.analysis import zensan
from repro.obs import trace as obs_trace
from repro.serving.engine import EngineStats, ServingEngine
from repro.serving.kv_cache import Request


def replica_view_name(app: str, idx: int) -> str:
    """Replica 0 keeps the bare app name (the handle's primary engine,
    stable across scaling); later replicas get suffixed view names."""
    return app if idx == 0 else f"{app}@r{idx}"


@dataclass
class Replica:
    """One engine lane of a ReplicaSet."""

    idx: int
    engine: ServingEngine
    runner: Optional[object] = None

    @property
    def load(self) -> int:
        return len(self.engine.running) + len(self.engine.queue)

    @property
    def headroom(self) -> int:
        return self.engine.max_batch - self.load


class ReplicaSet:
    """The data plane of one app: N engine replicas behind the router.

    ``build`` is an executor-provided factory ``(idx) -> Replica``; the
    set owns replica lifecycle (add / drain-and-remove / batch width)
    while the :class:`~repro.autoscale.controller.AutoscaleController`
    stays pure control plane -- the grl2-style controller/manager split.
    """

    def __init__(self, app: str, build: Callable[[int], Replica], *,
                 initial: int = 1, app_weight: float = 1.0,
                 quota_pages: Optional[int] = None):
        self.app = app
        self._build = build
        self._next_idx = 0
        self.replicas: List[Replica] = []
        self.app_weight = app_weight
        self.quota_pages = quota_pages if isinstance(quota_pages, int) else None
        self.router: Optional["RequestRouter"] = None
        #: counters of replicas removed since birth (aggregated stats must
        #: stay monotonic when a replica's engine is discarded)
        self.retired = EngineStats()
        self.replicas_added = 0
        self.replicas_removed = 0
        try:
            for _ in range(max(initial, 1)):
                self.add_replica()
        except Exception:
            self.shutdown()
            raise

    @property
    def primary(self) -> Replica:
        """The replica behind ``AppHandle.engine`` (idx 0 never drains:
        remove picks the highest index)."""
        return self.replicas[0]

    # -- scaling dimensions --------------------------------------------------
    def add_replica(self) -> Replica:
        rep = self._build(self._next_idx)
        self._next_idx += 1
        self.replicas.append(rep)
        self.replicas_added += 1
        self._rebalance()
        t = obs_trace.TRACER
        if t is not None:
            t.instant("autoscale", "replica_add", self.app,
                      {"replica": rep.idx, "num_replicas": len(self.replicas)})
        return rep

    def remove_replica(self) -> Dict:
        """Drain the highest-index replica and migrate its in-flight
        requests to the least-loaded survivor; returns the migration
        receipt."""
        if len(self.replicas) <= 1:
            raise RuntimeError(f"{self.app}: cannot remove the last replica "
                               "(scale-to-zero is park)")
        victim = max(self.replicas, key=lambda r: r.idx)
        self.replicas.remove(victim)
        receipt = self._migrate(victim)
        for f in EngineStats.COUNTERS:
            setattr(self.retired, f, getattr(self.retired, f)
                    + getattr(victim.engine.stats, f))
        victim.engine.shutdown()        # frees nothing (drained); closes view
        self.replicas_removed += 1
        self._rebalance()
        t = obs_trace.TRACER
        if t is not None:
            t.instant("autoscale", "replica_remove", self.app,
                      {"replica": victim.idx,
                       "num_replicas": len(self.replicas), **receipt})
        return receipt

    def scale_to(self, n: int) -> Dict:
        n = max(int(n), 1)
        receipt: Dict = {"migrated_requests": 0, "requeued_requests": 0}
        while len(self.replicas) < n:
            self.add_replica()
        while len(self.replicas) > n:
            r = self.remove_replica()
            receipt["migrated_requests"] += r.get("migrated_requests", 0)
            receipt["requeued_requests"] += r.get("requeued_requests", 0)
        receipt["num_replicas"] = len(self.replicas)
        return receipt

    def set_max_batch(self, n: int) -> int:
        """Set the continuous-batch admission width on every replica,
        clamped to each runner's build-time compile-shape cap (both
        backends pad decode to the runner's ``max_batch``; growing past
        it would retrace or index out of the dense slot range).  Returns
        the width actually applied."""
        n = max(int(n), 1)
        applied = []
        for r in self.replicas:
            cap = getattr(r.runner, "max_batch", None)
            nb = min(n, cap) if cap else n
            r.engine.max_batch = nb
            applied.append(nb)
        return min(applied) if applied else n

    @property
    def max_batch(self) -> int:
        return min((r.engine.max_batch for r in self.replicas), default=0)

    def _rebalance(self) -> None:
        """Replica views split the app's tenancy evenly: the app's weight
        (and integer quota, when one was set) is divided across its
        replicas so scaling out never grows the app's fair share at
        co-tenants' expense."""
        n = len(self.replicas)
        if n == 0:
            return
        for r in self.replicas:
            view = r.engine.pool
            if hasattr(view, "weight"):
                view.weight = self.app_weight / n
            if self.quota_pages is not None and hasattr(view, "resize_quota"):
                view.resize_quota(max(self.quota_pages // n, 1))

    # -- replica-to-replica migration ----------------------------------------
    def _migrate(self, victim: Replica) -> Dict:
        """Hand the victim's work to survivors: queued requests go back to
        the router front; running ones drain (pages reclaimed, KV intact)
        and either re-grant + scatter on the least-loaded survivor
        (token-identical) or requeue from scratch."""
        target = min(self.replicas, key=lambda r: r.load)
        veng, teng = victim.engine, target.engine
        queued = list(veng.queue)
        veng.queue.clear()
        drained = veng.drain()
        state = (victim.runner.migrate_out(drained)
                 if victim.runner is not None else None)
        # token-identical continuation needs a shared physical KV array
        # set; a runner that can't migrate (dense slots) requeues all
        migratable = (victim.runner is None
                      or getattr(victim.runner, "can_migrate", False))
        reattach = getattr(target.runner, "prefix_reattach", None)
        restored: List[Request] = []
        requeued: List[Request] = []
        for req, (g_ids, l_ids) in drained:
            ok = False
            if (migratable
                    and len(teng.running) + len(restored) < teng.max_batch):
                # same re-grant discipline as unpark: prefix re-pin first
                # (the snapshot is private pages only), then exact-count
                # re-grant on the TARGET view, reclaiming under pressure
                if reattach is None or reattach(req):
                    ok = teng.pool.regrant(req, len(g_ids), len(l_ids))
                    while not ok:
                        if not teng._reclaim():
                            break
                        ok = teng.pool.regrant(req, len(g_ids), len(l_ids))
                    if not ok:
                        teng.pool.prefix_detach(req)
                else:
                    teng.pool.prefix_detach(req)
            (restored if ok else requeued).append(req)
        if victim.runner is not None and restored:
            target.runner.migrate_in(state, restored)
        teng.running.extend(restored)
        s = zensan.SAN
        for req in requeued:            # at-least-once fallback
            req.generated = 0
            req.state = "queued"
        if s is not None:
            # every drained request holds a park receipt on the VICTIM
            # view (its regrant above landed on the target's ledger key):
            # resolve them all, then assert none went stranded before the
            # view closes
            for req, _ in drained:
                s.park_cancel(veng.pool, req.req_id)
            s.unpark_done(veng.pool, getattr(veng.pool, "app", self.app))
            s.check(veng.pool)
        if self.router is not None:
            self.router.requeue(self.app, requeued + queued)
        else:
            for req in reversed(requeued + queued):
                teng.queue.appendleft(req)
        t = obs_trace.TRACER
        if t is not None:
            for req in restored:
                t.instant("request", "migrate", req.req_id,
                          {"app": self.app, "from": victim.idx,
                           "to": target.idx, "restored": True})
            for req in requeued:
                t.instant("request", "migrate", req.req_id,
                          {"app": self.app, "from": victim.idx,
                           "to": target.idx, "restored": False})
        return {"migrated_requests": len(restored),
                "requeued_requests": len(requeued) + len(queued)}

    def shutdown(self) -> None:
        # primary last: if it is the store's final active user, its view
        # close drops the shared device arrays exactly once
        for r in sorted(self.replicas, key=lambda r: -r.idx):
            r.engine.shutdown()
        self.replicas.clear()


@dataclass
class _AppEntry:
    rset: ReplicaSet
    queue: Deque[Request] = field(default_factory=collections.deque)
    submitted: int = 0
    dispatched: int = 0


class RequestRouter:
    """Pod-level front door: one queue per app, continuous dispatch."""

    def __init__(self, pod: str = "pod"):
        self.pod = pod
        self.apps: Dict[str, _AppEntry] = {}

    def register(self, app: str, rset: ReplicaSet) -> None:
        if app in self.apps:
            raise ValueError(f"router({self.pod}): app {app!r} already "
                             "registered")
        self.apps[app] = _AppEntry(rset=rset)
        rset.router = self

    def unregister(self, app: str) -> None:
        entry = self.apps.pop(app, None)
        if entry is not None:
            entry.rset.router = None

    # -- ingress -------------------------------------------------------------
    def submit(self, app: str, req: Request) -> None:
        entry = self.apps[app]
        # arrival is stamped HERE, once: dispatch passes it through so
        # TTFT includes router-queue wait, not just engine-queue wait
        req.submitted_at = time.perf_counter()
        entry.queue.append(req)
        entry.submitted += 1
        self._dispatch(entry)

    def requeue(self, app: str, reqs: List[Request]) -> None:
        """Migration fallback: requests re-enter at the FRONT in order
        (they were admitted before anything currently waiting)."""
        entry = self.apps[app]
        entry.queue.extendleft(reversed(reqs))

    def queue_len(self, app: str) -> int:
        entry = self.apps.get(app)
        return len(entry.queue) if entry is not None else 0

    # -- dispatch + stepping -------------------------------------------------
    def _dispatch(self, entry: _AppEntry) -> int:
        """Join-shortest-queue among replicas with batch headroom; a
        request binds to a lane only when that lane can actually take
        it, otherwise it waits here (late binding)."""
        moved = 0
        t = obs_trace.TRACER
        while entry.queue:
            ready = [r for r in entry.rset.replicas if r.headroom > 0]
            if not ready:
                break
            target = min(ready, key=lambda r: (r.load, r.idx))
            req = entry.queue.popleft()
            target.engine.submit(req, submitted_at=req.submitted_at)
            entry.dispatched += 1
            moved += 1
            if t is not None:
                t.instant("request", "route", req.req_id,
                          {"app": entry.rset.app, "replica": target.idx,
                           "queue": len(entry.queue)})
        return moved

    def step_app(self, app: str) -> bool:
        """Dispatch + step every replica of one app.  Returns True while
        the app still has work anywhere (router queue included)."""
        entry = self.apps[app]
        self._dispatch(entry)
        alive = False
        for r in list(entry.rset.replicas):
            alive = r.engine.step() or alive
        return alive or bool(entry.queue)

    def step(self) -> bool:
        """One round over every registered app (round-robin by
        construction: each app gets exactly one dispatch+step per
        round)."""
        alive = False
        for app in list(self.apps):
            if app in self.apps:
                alive = self.step_app(app) or alive
        return alive

    def stats(self, app: str) -> Dict:
        entry = self.apps.get(app)
        if entry is None:
            return {}
        return {"queue_len": len(entry.queue),
                "submitted": entry.submitted,
                "dispatched": entry.dispatched,
                "num_replicas": len(entry.rset.replicas),
                "replicas_added": entry.rset.replicas_added,
                "replicas_removed": entry.rset.replicas_removed,
                "max_batch": entry.rset.max_batch}
