"""Model execution backends for the serving engine.

The :class:`~repro.serving.engine.ServingEngine` owns admission, paging
and preemption; a :class:`ModelRunner` owns the device state and the two
model entry points the engine drives:

* ``prefill(req)``  -- full forward over the prompt, caching KV.
* ``decode(running)`` -- one batched greedy decode step.

Two implementations:

* :class:`DenseRunner` -- per-slot dense KV cache of ``cache_len`` tokens
  (the previous inline executor closure, extracted).  Decode attends over
  a contiguous cache via ``model.decode_step``; positions are shared
  across the batch (the historical approximation).
* :class:`PagedRunner` -- KV lives in the ``(pool_pages, PAGE_SIZE, KV,
  hd)`` layout granted page-by-page by the engine's pool; decode attends
  through :func:`repro.kernels.ops.paged_attention` (Pallas kernel on
  TPU, interpreted ref path on CPU) driven by each request's page table.
  Positions and valid lengths are exact per request, so co-batched
  requests of different lengths decode correctly -- and the KV footprint
  is the pages the sizing policy granted, not ``max_batch * cache_len``.

Prompt tokens are synthesized from a *stable* digest of the request id
(``zlib.crc32``): ``hash()`` is salted per process, which made served
outputs nondeterministic across runs.
"""

from __future__ import annotations

import zlib
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import _from_saved, _to_savable
from repro.configs.base import ATTN_GLOBAL, ModelConfig
from repro.kernels import ops
from repro.kernels.paged_attention import paged_attention_ref
from repro.models import ImplConfig, build_model
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving.kv_cache import PAGE_SIZE, Request, page_table

KV_DTYPE = jnp.bfloat16


def synth_prompt(req_id: str, prompt_len: int, vocab: int) -> jax.Array:
    """Deterministic synthetic prompt: stable across processes and runs."""
    seed = zlib.crc32(req_id.encode()) % 2**31
    return jax.random.randint(jax.random.PRNGKey(seed), (1, prompt_len),
                              0, vocab)


class ModelRunner:
    """Backend interface the engine's step functions are bound to."""

    backend = "null"

    def __init__(self):
        self.engine = None
        self.generated: Dict[str, List[int]] = {}

    def bind(self, engine) -> None:
        self.engine = engine

    def prefill(self, req: Request) -> None:
        raise NotImplementedError

    def decode(self, running: List[Request]) -> None:
        raise NotImplementedError

    # -- idle parking (repro.autoscale.parking) ------------------------------
    @staticmethod
    def _tree_to_host(tree) -> Tuple[list, Any]:
        """Checkpointer array format (bf16 stored as uint16 + logical
        dtype) for a whole pytree; the device copies become collectable."""
        leaves, treedef = jax.tree.flatten(tree)
        return ([_to_savable(np.asarray(jax.device_get(x)))
                 for x in leaves], treedef)

    @staticmethod
    def _tree_from_host(saved: Tuple[list, Any]):
        leaves, treedef = saved
        return jax.tree.unflatten(
            treedef, [jnp.asarray(_from_saved(a, d)) for a, d in leaves])

    def park(self, drained: List[Tuple[Request, List[int]]]) -> Dict:
        """Snapshot decode state AND params to host (checkpointer array
        format) and DROP the device copies, so a parked app's HBM is
        actually reclaimable -- the scheduler hands back 100% of the
        job's bytes, which must not leave weights silently resident.
        ``drained`` is the engine's ``drain()`` output: (request, page
        ids it held), with the page contents still intact on device."""
        state = {"generated": {k: list(v)
                               for k, v in self.generated.items()}}
        if getattr(self, "params", None) is not None:
            state["params"] = self._tree_to_host(self.params)
            self.params = None
        return state

    def unpark(self, state: Dict, restored: List[Request]) -> None:
        """Rebuild device state from a ``park`` snapshot.  ``restored``
        are the drained requests that re-acquired pages (their
        ``req.pages`` are fresh ids); requests that could not be
        re-granted are re-queued by the caller and re-prefill from
        scratch."""
        if "params" in state:
            self.params = self._tree_from_host(state["params"])
        self.generated = {k: list(v) for k, v in state["generated"].items()}


class DenseRunner(ModelRunner):
    """Slot-indexed dense KV cache; decode via ``model.decode_step``."""

    backend = "dense"

    def __init__(self, cfg: ModelConfig, *, seed: int = 0, max_batch: int = 4,
                 cache_len: int = 256):
        super().__init__()
        self.cfg = cfg
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.model = build_model(cfg, ImplConfig(remat="none"))
        self.params = self.model.init_params(jax.random.PRNGKey(seed))
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len))
        self.cache = self.model.init_cache(max_batch, cache_len)
        self.slots: Dict[str, Any] = {}

    def prefill(self, req: Request) -> None:
        toks = synth_prompt(req.req_id, req.prompt_len, self.cfg.vocab_size)
        logits, rc = self._prefill(self.params, {"tokens": toks})
        # evict slots of preempted requests (the engine re-queues them;
        # only completion frees a slot in decode) before picking one
        running_ids = {r.req_id for r in self.engine.running}
        for rid in list(self.slots):
            if rid not in running_ids:
                del self.slots[rid]
        if req.req_id in self.slots:      # re-admission after preemption
            slot = self.slots[req.req_id][0]
        else:
            slot = min(set(range(self.max_batch))
                       - {s for s, _ in self.slots.values()})
        self.slots[req.req_id] = (slot, req.prompt_len)
        self.cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1),
            self.cache, rc)
        self.generated[req.req_id] = [int(jnp.argmax(logits[0, -1]))]

    def decode(self, running: List[Request]) -> None:
        if not running:
            return
        toks = np.zeros((self.max_batch, 1), np.int32)
        pos = 0
        for req in running:
            slot, plen = self.slots[req.req_id]
            toks[slot, 0] = self.generated[req.req_id][-1]
            pos = max(pos, plen + req.generated)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        for req in running:
            slot, _ = self.slots[req.req_id]
            self.generated[req.req_id].append(int(nxt[slot]))
            if req.generated + 1 >= req.max_new_tokens:
                self.slots.pop(req.req_id, None)

    def park(self, drained):
        """The dense cache is one contiguous tree: snapshot every leaf to
        host and drop the device copy."""
        state = super().park(drained)
        state["cache"] = self._tree_to_host(self.cache)
        state["slots"] = dict(self.slots)
        self.cache = None
        return state

    def unpark(self, state, restored):
        super().unpark(state, restored)
        self.cache = self._tree_from_host(state["cache"])
        self.slots = dict(state["slots"])


class PagedRunner(ModelRunner):
    """KV in pool pages; decode through the paged-attention kernel.

    Supports RoPE global-attention stacks (llama-family patterns); other
    block kinds (SWA rings, SSM state, cross attention) keep the dense
    backend until they grow paged layouts.

    Device-memory note: each runner holds its OWN page arrays sized to
    the physical pool (tenants run different models, so their KV arrays
    cannot alias).  The pod's :class:`SharedPagePool` bounds the
    *accounted* combined footprint; true on-device sharing of one array
    set across same-model tenants needs a view-local page-id remap
    (ROADMAP).
    """

    backend = "paged"

    def __init__(self, cfg: ModelConfig, *, seed: int = 0,
                 pool_pages: int = 128):
        super().__init__()
        if (any(k != ATTN_GLOBAL for k in cfg.pattern)
                or cfg.rope_theta <= 0 or cfg.is_encdec
                or cfg.family in ("vlm", "audio")):
            raise ValueError(
                f"backend='paged' supports global-attention RoPE stacks; "
                f"{cfg.name} has pattern={cfg.pattern}")
        self.cfg = cfg
        self.model = build_model(cfg, ImplConfig(remat="none"))
        self.params = self.model.init_params(jax.random.PRNGKey(seed))
        self._prefill = jax.jit(self.model.prefill, static_argnums=2)
        nb, pat = cfg.num_blocks, len(cfg.pattern)
        self.num_layers = nb * pat
        self.page_shape = (pool_pages, PAGE_SIZE, cfg.num_kv_heads,
                           cfg.head_dim)
        shape = self.page_shape
        self.k_pages = [jnp.zeros(shape, KV_DTYPE) for _ in range(nb * pat)]
        self.v_pages = [jnp.zeros(shape, KV_DTYPE) for _ in range(nb * pat)]
        # the Pallas kernel natively on TPU; its jnp oracle elsewhere (the
        # interpreted kernel is validated against the oracle in
        # tests/test_kernels.py, and is ~60x slower than the oracle on CPU)
        self._paged_attn = (ops.paged_attention
                            if jax.default_backend() == "tpu"
                            else paged_attention_ref)
        # page arrays are donated: XLA updates them in place instead of
        # copying the whole pool per layer per token
        self._decode = jax.jit(self._decode_fn, donate_argnums=(7, 8))
        self._scatter = jax.jit(self._scatter_fn, donate_argnums=(0, 1))

    @staticmethod
    def _scatter_fn(kp, vp, pages, k, v):
        return (kp.at[pages].set(k.astype(KV_DTYPE)),
                vp.at[pages].set(v.astype(KV_DTYPE)))

    def prefill(self, req: Request) -> None:
        """Forward over the prompt, then scatter its KV into the request's
        granted pages (page p holds tokens [p*PAGE, (p+1)*PAGE))."""
        assert req.pages, f"{req.req_id}: prefill before admission"
        cfg = self.cfg
        toks = synth_prompt(req.req_id, req.prompt_len, cfg.vocab_size)
        cache_len = len(req.pages) * PAGE_SIZE
        logits, cache = self._prefill(self.params, {"tokens": toks},
                                      cache_len)
        pages = jnp.asarray(req.pages, jnp.int32)
        for layer in range(len(self.k_pages)):
            j, i = divmod(layer, len(cfg.pattern))
            kv = cache[f"p{i}_{cfg.pattern[i]}"]
            # (nb, 1, KV, cache_len, hd) -> (n_pages, PAGE, KV, hd)
            k = kv["k"][j, 0].transpose(1, 0, 2).reshape(
                len(req.pages), PAGE_SIZE, cfg.num_kv_heads, cfg.head_dim)
            v = kv["v"][j, 0].transpose(1, 0, 2).reshape(
                len(req.pages), PAGE_SIZE, cfg.num_kv_heads, cfg.head_dim)
            self.k_pages[layer], self.v_pages[layer] = self._scatter(
                self.k_pages[layer], self.v_pages[layer], pages, k, v)
        self.generated[req.req_id] = [int(jnp.argmax(logits[0, -1]))]

    def _decode_fn(self, params, toks, positions, phys, off, table, vlen,
                   k_pages, v_pages):
        """One batched decode step over the whole stack (jitted; the page
        arrays are donated so per-layer writes happen in place)."""
        cfg = self.cfg
        new_k, new_v = list(k_pages), list(v_pages)
        x = self.model._embed(params, toks)
        for layer in range(len(new_k)):
            j, i = divmod(layer, len(cfg.pattern))
            bp = jax.tree.map(lambda a: a[j],
                              params["blocks"][f"p{i}_{cfg.pattern[i]}"])
            h = T.apply_norm(cfg, bp["ln1"], x)
            q, k, v = attn.project_qkv(bp["attn"], h, cfg, positions)
            kp = new_k[layer].at[phys, off].set(k[:, 0].astype(KV_DTYPE))
            vp = new_v[layer].at[phys, off].set(v[:, 0].astype(KV_DTYPE))
            new_k[layer], new_v[layer] = kp, vp
            o = self._paged_attn(q[:, 0], kp, vp, table, vlen)
            x = x + attn.attn_out(bp["attn"], o[:, None])
            h = T.apply_norm(cfg, bp["ln2"], x)
            x = x + L.gated_mlp(bp["mlp"], h)
        x = T.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(params["embed"], x, cfg.logit_softcap)
        return jnp.argmax(logits[:, -1], -1), new_k, new_v

    def decode(self, running: List[Request]) -> None:
        if not running:
            return
        pos = np.asarray([r.length for r in running])     # write positions
        for r, p in zip(running, pos):
            if p // PAGE_SIZE >= len(r.pages):
                raise RuntimeError(
                    f"{r.req_id}: token {p} beyond granted pages "
                    f"({len(r.pages)}) -- engine must grow with horizon=1")
        toks = jnp.asarray([[self.generated[r.req_id][-1]] for r in running],
                           jnp.int32)
        maxp = max(len(r.pages) for r in running)
        table = jnp.asarray(page_table(running, maxp))
        vlen = jnp.asarray(pos + 1, jnp.int32)
        positions = jnp.asarray(pos, jnp.int32)[:, None]  # (B, 1) exact
        phys = jnp.asarray([r.pages[p // PAGE_SIZE]
                            for r, p in zip(running, pos)], jnp.int32)
        off = jnp.asarray(pos % PAGE_SIZE, jnp.int32)
        nxt, self.k_pages, self.v_pages = self._decode(
            self.params, toks, positions, phys, off, table, vlen,
            self.k_pages, self.v_pages)
        nxt = np.asarray(nxt)
        for b, req in enumerate(running):
            self.generated[req.req_id].append(int(nxt[b]))

    def park(self, drained):
        """Gather each drained request's KV pages to host (one
        (layers, n_pages, PAGE, KV, hd) array per request, page ids
        dropped -- unpark scatters into whatever fresh ids are granted)
        and free the pool-sized device arrays, the bulk of a serve app's
        HBM footprint."""
        state = super().park(drained)
        kv = {}
        for req, page_ids in drained:
            idx = jnp.asarray(page_ids, jnp.int32)
            k = np.stack([np.asarray(kp[idx]) for kp in self.k_pages])
            v = np.stack([np.asarray(vp[idx]) for vp in self.v_pages])
            kv[req.req_id] = (_to_savable(k), _to_savable(v))
        state["kv"] = kv
        self.k_pages = None
        self.v_pages = None
        return state

    def unpark(self, state, restored):
        super().unpark(state, restored)
        self.k_pages = [jnp.zeros(self.page_shape, KV_DTYPE)
                        for _ in range(self.num_layers)]
        self.v_pages = [jnp.zeros(self.page_shape, KV_DTYPE)
                        for _ in range(self.num_layers)]
        for req in restored:
            (ka, kd), (va, vd) = state["kv"][req.req_id]
            k = jnp.asarray(_from_saved(ka, kd))     # (L, n, PAGE, KV, hd)
            v = jnp.asarray(_from_saved(va, vd))
            pages = jnp.asarray(req.pages, jnp.int32)
            for layer in range(self.num_layers):
                self.k_pages[layer], self.v_pages[layer] = self._scatter(
                    self.k_pages[layer], self.v_pages[layer], pages,
                    k[layer], v[layer])


def build_runner(backend: str, cfg: ModelConfig, *, seed: int = 0,
                 max_batch: int = 4, cache_len: int = 256,
                 pool_pages: int = 128) -> ModelRunner:
    """Factory keyed by ``Application.options['backend']``."""
    if backend == "dense":
        return DenseRunner(cfg, seed=seed, max_batch=max_batch,
                           cache_len=cache_len)
    if backend == "paged":
        return PagedRunner(cfg, seed=seed, pool_pages=pool_pages)
    raise ValueError(f"unknown serving backend {backend!r} "
                     "(expected 'dense' or 'paged')")
