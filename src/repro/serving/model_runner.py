"""Model execution backends for the serving engine.

The :class:`~repro.serving.engine.ServingEngine` owns admission, paging
and preemption; a :class:`ModelRunner` owns the device state and the two
model entry points the engine drives:

* ``prefill(req)``  -- full forward over the prompt, caching KV.
* ``decode(running)`` -- one batched greedy decode step.

Two implementations:

* :class:`DenseRunner` -- per-slot dense KV cache of ``cache_len`` tokens
  (the previous inline executor closure, extracted).  Decode attends over
  a contiguous cache via ``model.decode_step``; positions are shared
  across the batch (the historical approximation).
* :class:`PagedRunner` -- KV lives in the ``(pool_pages, PAGE_SIZE, KV,
  hd)`` layout granted page-by-page by the engine's pool; decode attends
  through :func:`repro.kernels.ops.paged_attention` (Pallas kernel on
  TPU, interpreted ref path on CPU) driven by each request's page table.
  Positions and valid lengths are exact per request, so co-batched
  requests of different lengths decode correctly -- and the KV footprint
  is the pages the sizing policy granted, not ``max_batch * cache_len``.
  Mixed global/sliding-window stacks (gemma3-style) are supported:
  ATTN_LOCAL layers keep a fixed *ring* of ``ceil(window/PAGE_SIZE)+1``
  pages per request (see :class:`~repro.serving.kv_cache.PageGroups`)
  while global layers keep the growing table.  The device page arrays
  live in a :class:`KVArrayStore`; same-KV-shape tenants on one pod
  alias ONE store (physical sharing), with requests carrying view-local
  page ids remapped to physical ids at kernel time.

Compile discipline (long-run serving must not recompile per step):

* decode pads the batch to ``max_batch`` (idle lanes write into a trash
  page and are fully masked) and buckets the page-table width to the
  next power of two, so a bursty run triggers O(log pool) decode
  compiles, not O(steps);
* prefill scatters prompt KV page-by-page straight from a
  prompt-length-bucketed forward -- no dense ``n_pages * PAGE_SIZE``
  cache is ever built, so there is no per-grant-size recompile and no
  transient dense allocation.

Prompt tokens are synthesized from a *stable* digest of the request id
(``zlib.crc32``): ``hash()`` is salted per process, which made served
outputs nondeterministic across runs.
"""

from __future__ import annotations

import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import zensan
from repro.checkpoint.checkpointer import _from_saved, _to_savable
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig
from repro.obs import trace as obs_trace
from repro.kernels import ops
from repro.kernels.paged_attention import paged_attention_ref
from repro.models import ImplConfig, build_model
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving.kv_cache import (PAGE_SIZE, PageGroups, Request,
                                    page_table)

KV_DTYPE = jnp.bfloat16


def kv_shape_key(cfg: ModelConfig, pool_pages: int, *,
                 use_rings: bool = True) -> Tuple:
    """KV shape signature deciding which paged tenants may alias one
    physical device array set: layer count, pool geometry, KV head
    layout, dtype, and (when rings are on) WHICH layers are rings --
    ring layers are indexed from the local id space, so a ring tenant
    and a no-ring tenant of the same config must not share arrays."""
    groups = PageGroups.from_config(cfg)
    rings = bool(use_rings) and groups.local_layers > 0
    return (cfg.num_blocks * len(cfg.pattern), int(pool_pages), PAGE_SIZE,
            cfg.num_kv_heads, cfg.head_dim, jnp.dtype(KV_DTYPE).name,
            tuple(k == ATTN_LOCAL for k in cfg.pattern) if rings else None)


class KVArrayStore:
    """One pod's physical KV page arrays for one KV shape: the aliasing
    unit of multi-tenant serving.

    Registered on the pod's :class:`~repro.serving.tenancy.SharedPagePool`
    keyed by :func:`kv_shape_key`; every same-shape paged tenant's
    :class:`PagedRunner` reads and writes THESE arrays (per-layer
    ``(pool_pages + 1, PAGE_SIZE, KV, hd)``, last slot = shared trash
    page), indexed by pod-unique physical page ids.  N same-model
    tenants therefore cost ONE pool of device HBM instead of N -- the
    pool's accounted footprint and the live footprint finally coincide.

    The arrays are engine-owned state, not any single runner's: jitted
    prefill/decode still donate them (in-place XLA updates), but each
    runner writes the donated result back here so co-tenants observe it.
    ``free_local`` is the shared physical id space for sliding-window
    ring pages (local-attention layers' arrays are shared too); it is
    None for shapes without rings.
    """

    def __init__(self, key: Tuple):
        (num_layers, pool_pages, page, kvh, hd, dtype, ring_pat) = key
        self.key = key
        self.num_layers = num_layers
        self.dtype = dtype
        self.page_shape = (pool_pages + 1, page, kvh, hd)
        self.k_pages: Optional[List[jax.Array]] = None
        self.v_pages: Optional[List[jax.Array]] = None
        self.free_local: Optional[List[int]] = (
            list(range(pool_pages)) if ring_pat and any(ring_pat) else None)
        self.users: set = set()     # app names aliasing this store
        self.ensure_arrays()

    def ensure_arrays(self) -> None:
        """(Re)materialize the device arrays -- parking the sole tenant
        drops them, and a later same-shape tenant (or unpark) needs them
        back."""
        if self.k_pages is None:
            self.k_pages = [jnp.zeros(self.page_shape, self.dtype)
                            for _ in range(self.num_layers)]
            self.v_pages = [jnp.zeros(self.page_shape, self.dtype)
                            for _ in range(self.num_layers)]

    def drop_arrays(self) -> None:
        self.k_pages = None
        self.v_pages = None

    def device_bytes(self) -> int:
        """Live device bytes of the page arrays (0 while parked-dropped)."""
        if self.k_pages is None:
            return 0
        return sum(int(a.nbytes) for a in self.k_pages) + \
            sum(int(a.nbytes) for a in self.v_pages)


def synth_prompt(req_id: str, prompt_len: int, vocab: int) -> jax.Array:
    """Deterministic synthetic prompt: stable across processes and runs."""
    seed = zlib.crc32(req_id.encode()) % 2**31
    return jax.random.randint(jax.random.PRNGKey(seed), (1, prompt_len),
                              0, vocab)


def prompt_for(req: Request, vocab: int) -> jax.Array:
    """(1, prompt_len) prompt tokens for a request.  An explicit
    ``req.prompt_tokens`` (benchmarks/tests controlling prompt overlap)
    wins; otherwise the usual deterministic synthesis.  BOTH backends go
    through here, so dense-vs-paged parity holds for either source."""
    if req.prompt_tokens is not None:
        assert len(req.prompt_tokens) == req.prompt_len
        return jnp.asarray(req.prompt_tokens, jnp.int32)[None, :]
    return synth_prompt(req.req_id, req.prompt_len, vocab)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class ModelRunner:
    """Backend interface the engine's step functions are bound to."""

    backend = "null"

    def __init__(self):
        self.engine = None
        self.generated: Dict[str, List[int]] = {}

    def bind(self, engine) -> None:
        self.engine = engine

    def prefill(self, req: Request) -> None:
        raise NotImplementedError

    def decode(self, running: List[Request]) -> None:
        raise NotImplementedError

    def finish(self, req: Request) -> None:
        """Completion hook (the engine calls this when a request is done):
        hand the tokens back to the request and evict every per-request
        runner entry -- a long-running engine must not accumulate state
        for requests that already left."""
        toks = self.generated.pop(req.req_id, None)
        if toks is not None:
            req.output_tokens = toks

    # -- idle parking (repro.autoscale.parking) ------------------------------
    @staticmethod
    def _tree_to_host(tree) -> Tuple[list, Any]:
        """Checkpointer array format (bf16 stored as uint16 + logical
        dtype) for a whole pytree; the device copies become collectable."""
        leaves, treedef = jax.tree.flatten(tree)
        return ([_to_savable(np.asarray(jax.device_get(x)))
                 for x in leaves], treedef)

    @staticmethod
    def _tree_from_host(saved: Tuple[list, Any]):
        leaves, treedef = saved
        return jax.tree.unflatten(
            treedef, [jnp.asarray(_from_saved(a, d)) for a, d in leaves])

    def park(self, drained: List[Tuple[Request, Tuple[List[int],
                                                      List[int]]]]) -> Dict:
        """Snapshot decode state AND params to host (checkpointer array
        format) and DROP the device copies, so a parked app's HBM is
        actually reclaimable -- the scheduler hands back 100% of the
        job's bytes, which must not leave weights silently resident.
        ``drained`` is the engine's ``drain()`` output: (request, (global
        page ids, local ring page ids) it held), with the page contents
        still intact on device."""
        state = {"generated": {k: list(v)
                               for k, v in self.generated.items()}}
        if getattr(self, "params", None) is not None:
            state["params"] = self._tree_to_host(self.params)
            self.params = None
        return state

    def unpark(self, state: Dict, restored: List[Request]) -> None:
        """Rebuild device state from a ``park`` snapshot.  ``restored``
        are the drained requests that re-acquired pages (their
        ``req.pages`` are fresh ids); requests that could not be
        re-granted are re-queued by the caller and re-prefill from
        scratch."""
        if "params" in state:
            self.params = self._tree_from_host(state["params"])
        self.generated = {k: list(v) for k, v in state["generated"].items()}

    # -- replica migration (serving.router.ReplicaSet) -----------------------
    #: replicas sharing one physical KV array set can hand running
    #: requests to each other without loss (paged); slot-indexed caches
    #: cannot (dense), so their requests take the requeue path instead
    can_migrate = False

    def migrate_out(self, drained: List[Tuple[Request, Tuple[List[int],
                                                             List[int]]]]
                    ) -> Dict:
        """Decode-state snapshot for replica-to-replica migration: park
        minus the params offload -- the surviving replicas keep serving,
        so weights stay on device and only the drained requests' state
        moves."""
        return {"generated": {req.req_id: self.generated.pop(req.req_id, [])
                              for req, _ in drained}}

    def migrate_in(self, state: Dict, restored: List[Request]) -> None:
        """Adopt migrated requests.  Unlike ``unpark`` (which REPLACES
        decode state wholesale), the target's own running requests keep
        theirs: only the restored requests' entries merge in."""
        for req in restored:
            self.generated[req.req_id] = list(
                state["generated"].get(req.req_id, []))


class DenseRunner(ModelRunner):
    """Slot-indexed dense KV cache; decode via ``model.decode_step``."""

    backend = "dense"

    def __init__(self, cfg: ModelConfig, *, seed: int = 0, max_batch: int = 4,
                 cache_len: int = 256):
        super().__init__()
        self.cfg = cfg
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.model = build_model(cfg, ImplConfig(remat="none"))
        self.params = self.model.init_params(jax.random.PRNGKey(seed))

        # compile attribution: the tracer instants fire at XLA trace
        # time (Python, shapes are static ints), so each marks one
        # compile of this backend, not one call
        def _decode_body(p, toks, cache, pos):
            t = obs_trace.TRACER
            if t is not None:
                t.instant("compile", "decode_trace", None,
                          {"backend": "dense", "batch": toks.shape[0]})
            return self.model.decode_step(p, toks, cache, pos)

        def _prefill_body(p, b):
            t = obs_trace.TRACER
            if t is not None:
                t.instant("compile", "prefill_trace", None,
                          {"backend": "dense",
                           "tokens": b["tokens"].shape[1]})
            return self.model.prefill(p, b, cache_len)

        self._decode = jax.jit(_decode_body)
        self._prefill = jax.jit(_prefill_body)
        self.cache = self.model.init_cache(max_batch, cache_len)
        self.slots: Dict[str, Any] = {}

    def prefill(self, req: Request) -> None:
        toks = prompt_for(req, self.cfg.vocab_size)
        # zenlint: ignore[ZL003] -- dense prefill compiles per distinct
        # prompt length BY DESIGN: this backend also serves recurrent
        # families (SSM/RWKV) whose prefill state after padded tokens
        # cannot be masked back out, so length bucketing would change
        # outputs; the paged backend is the O(1)-compile serving path.
        logits, rc = self._prefill(self.params, {"tokens": toks})
        # evict slots of preempted requests (the engine re-queues them;
        # only completion frees a slot via finish) before picking one
        running_ids = {r.req_id for r in self.engine.running}
        for rid in list(self.slots):
            if rid not in running_ids:
                del self.slots[rid]
        if req.req_id in self.slots:      # re-admission after preemption
            slot = self.slots[req.req_id][0]
        else:
            slot = min(set(range(self.max_batch))
                       - {s for s, _ in self.slots.values()})
        self.slots[req.req_id] = (slot, req.prompt_len)
        self.cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1),
            self.cache, rc)
        # zenlint: ignore[ZL004] -- first-token extraction: prefill is
        # once per request (not per token) and the engine needs the
        # token id to seed decode; this is the designed sync point.
        self.generated[req.req_id] = [int(jnp.argmax(logits[0, -1]))]

    def decode(self, running: List[Request]) -> None:
        if not running:
            return
        s = zensan.SAN
        if s is not None:
            s.dense_state(self, running)
        toks = np.zeros((self.max_batch, 1), np.int32)
        pos = 0
        for req in running:
            slot, plen = self.slots[req.req_id]
            toks[slot, 0] = self.generated[req.req_id][-1]
            pos = max(pos, plen + req.generated)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(pos, jnp.int32))
        # zenlint: ignore[ZL004] -- THE one batched device->host fetch
        # per decode step: every lane's next token in a single transfer.
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        for req in running:
            slot, _ = self.slots[req.req_id]
            self.generated[req.req_id].append(int(nxt[slot]))

    def finish(self, req: Request) -> None:
        super().finish(req)
        self.slots.pop(req.req_id, None)

    def park(self, drained):
        """The dense cache is one contiguous tree: snapshot every leaf to
        host and drop the device copy."""
        state = super().park(drained)
        state["cache"] = self._tree_to_host(self.cache)
        state["slots"] = dict(self.slots)
        self.cache = None
        return state

    def unpark(self, state, restored):
        super().unpark(state, restored)
        self.cache = self._tree_from_host(state["cache"])
        self.slots = dict(state["slots"])


class PagedRunner(ModelRunner):
    """KV in pool pages; decode through the paged-attention kernel.

    Supports RoPE decoder-only stacks mixing ATTN_GLOBAL and ATTN_LOCAL
    blocks (llama- and gemma3-family patterns).  Global layers keep a
    page table that grows with sequence length; sliding-window layers
    keep a fixed per-request ring of ``PageGroups.ring_pages`` pages --
    decode writes token ``p`` at ring slot ``p % (ring_pages *
    PAGE_SIZE)`` and the kernel's ring masking recovers each slot's
    absolute position.  Other block kinds (SSM state, MoE, cross
    attention) keep the dense backend until they grow paged layouts.

    Device-memory note: the page arrays live in a :class:`KVArrayStore`
    -- pass ``kv_store=`` (the pod's registered store for this KV shape)
    and every same-shape tenant reads/writes ONE device allocation;
    without it the runner builds a private store (mismatched-shape and
    ``alias_kv=False`` tenants).  Requests carry view-local page ids; at
    kernel time the runner translates them through the engine pool's
    ``to_physical`` remap, so the kernel always indexes the arrays by
    pod-unique physical ids.  The last slot (index ``pool_pages``) is a
    write-only trash page for padded batch lanes.
    """

    backend = "paged"

    SUPPORTED_KINDS = (ATTN_GLOBAL, ATTN_LOCAL)

    def __init__(self, cfg: ModelConfig, *, seed: int = 0,
                 pool_pages: int = 128, max_batch: int = 4,
                 use_rings: bool = True,
                 kv_store: Optional[KVArrayStore] = None,
                 prefix_cache=None, chunk_pages: int = 4):
        super().__init__()
        if (any(k not in self.SUPPORTED_KINDS for k in cfg.pattern)
                or cfg.rope_theta <= 0 or cfg.is_encdec
                or cfg.family in ("vlm", "audio")):
            raise ValueError(
                f"backend='paged' supports RoPE global/sliding-window "
                f"attention stacks; {cfg.name} has pattern={cfg.pattern}")
        if ATTN_LOCAL in cfg.pattern and cfg.sliding_window <= 0:
            raise ValueError(f"{cfg.name}: ATTN_LOCAL needs sliding_window")
        self.cfg = cfg
        self.max_batch = max_batch
        self.groups = PageGroups.from_config(cfg)
        self.use_rings = use_rings and self.groups.local_layers > 0
        if prefix_cache is not None and self.groups.local_layers > 0:
            raise ValueError(
                f"prefix_cache=True needs a pure-global attention stack: "
                f"{cfg.name} has sliding-window layers whose ring pages "
                "cannot hold a position-stable shared prefix")
        self.prefix = prefix_cache
        self.chunk_pages = max(int(chunk_pages), 1)
        self.model = build_model(cfg, ImplConfig(remat="none"))
        self.params = self.model.init_params(jax.random.PRNGKey(seed))
        nb, pat = cfg.num_blocks, len(cfg.pattern)
        self.num_layers = nb * pat
        self.pool_pages = pool_pages
        self.trash_page = pool_pages            # padded lanes write here
        key = kv_shape_key(cfg, pool_pages, use_rings=self.use_rings)
        if kv_store is not None and kv_store.key != key:
            raise ValueError(
                f"kv_store shape mismatch for {cfg.name}: store key "
                f"{kv_store.key} != runner key {key} -- mismatched-shape "
                "tenants must fall back to private arrays")
        self.shared_kv = kv_store is not None
        self.store = kv_store if kv_store is not None else KVArrayStore(key)
        self.store.ensure_arrays()      # a parked-dropped store revives
        self.page_shape = self.store.page_shape
        # the Pallas kernel natively on TPU; its jnp oracle elsewhere (the
        # interpreted kernel is validated against the oracle in
        # tests/test_kernels.py, and is ~60x slower than the oracle on CPU)
        self._paged_attn = (ops.paged_attention
                            if jax.default_backend() == "tpu"
                            else paged_attention_ref)
        # compile-count observability: incremented at TRACE time, so each
        # attribute counts XLA compiles, not calls (regression-tested)
        self.decode_traces = 0
        self.prefill_traces = 0
        # prefill work actually computed, in pages (the prefix cache's
        # savings metric: cached pages never reach this counter)
        self.prefill_pages_computed = 0
        self.reattach_unpins = 0
        # page arrays are donated: XLA updates them in place instead of
        # copying the whole pool per layer per token
        self._decode = jax.jit(self._decode_fn, donate_argnums=(9, 10))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(6, 7))
        self._chunk = jax.jit(self._chunk_fn, donate_argnums=(8, 9))
        self._scatter = jax.jit(self._scatter_fn, donate_argnums=(0, 1))
        self._copy = jax.jit(self._copy_fn, donate_argnums=(0, 1))

    # the arrays live on the (possibly pod-shared) store; runner code and
    # tests read them through these aliases
    @property
    def k_pages(self) -> Optional[List[jax.Array]]:
        return self.store.k_pages

    @property
    def v_pages(self) -> Optional[List[jax.Array]]:
        return self.store.v_pages

    # -- view-local -> physical id translation -------------------------------
    def _phys(self, ids: List[int]) -> List[int]:
        """Physical ids of a request's global-table pages (identity for a
        private pool; the PoolView remap for pod-shared tenancy)."""
        pool = self.engine.pool if self.engine is not None else None
        return pool.to_physical(ids) if pool is not None else list(ids)

    def _phys_local(self, ids: List[int]) -> List[int]:
        pool = self.engine.pool if self.engine is not None else None
        return pool.to_physical_local(ids) if pool is not None else list(ids)

    def _layer_kind(self, layer: int) -> str:
        return self.cfg.pattern[layer % len(self.cfg.pattern)]

    def _layer_ring(self, layer: int) -> bool:
        """Whether this layer's table is a ring (vs a growing table)."""
        return self.use_rings and self._layer_kind(layer) == ATTN_LOCAL

    @staticmethod
    def _scatter_fn(kp, vp, pages, k, v):
        return (kp.at[pages].set(k.astype(KV_DTYPE)),
                vp.at[pages].set(v.astype(KV_DTYPE)))

    @staticmethod
    def _copy_fn(kp, vp, src, dst):
        """Copy-on-write page duplication (one layer's arrays, donated)."""
        return kp.at[dst].set(kp[src]), vp.at[dst].set(vp[src])

    def _cow_copy(self, src_phys: int, dst_phys: int) -> None:
        """Duplicate one physical page's KV across every layer (the
        insert-time self-COW: the donor keeps writing into the copy while
        the original becomes a read-only cached partial page)."""
        s = jnp.asarray(src_phys, jnp.int32)
        d = jnp.asarray(dst_phys, jnp.int32)
        for layer in range(self.num_layers):
            (self.store.k_pages[layer],
             self.store.v_pages[layer]) = self._copy(
                self.store.k_pages[layer], self.store.v_pages[layer], s, d)

    def _block_forward(self, bp, x, positions, mix):
        """One pattern block (the shared prefill/decode layer body).
        ``mix(q, k, v) -> (B, S, H, hd)`` carries the phase-specific
        part: writing KV into the page arrays and attending through the
        layer's table -- everything else must stay identical between the
        two phases or they diverge from dense in only one of them."""
        cfg = self.cfg
        h = T.apply_norm(cfg, bp["ln1"], x)
        q, k, v = attn.project_qkv(bp["attn"], h, cfg, positions)
        x = x + attn.attn_out(bp["attn"], mix(q, k, v))
        h = T.apply_norm(cfg, bp["ln2"], x)
        return x + L.gated_mlp(bp["mlp"], h)

    # -- prefill -------------------------------------------------------------
    def _prefill_fn(self, params, toks, last, g_ids, l_ids, l_src,
                    k_pages, v_pages):
        """Forward over the (page-padded) prompt, scattering each layer's
        KV page-by-page into the granted ids: no dense ``cache_len``
        cache, no per-grant-size recompile (the compile key is the padded
        prompt page count only).  ``last`` is the index of the final real
        prompt token; ``l_src`` names the prompt pages that survive in
        the ring (the last ``ring_pages`` of them)."""
        self.prefill_traces += 1
        t = obs_trace.TRACER
        if t is not None:
            t.instant("compile", "prefill_trace", None,
                      {"backend": "paged", "tokens": toks.shape[1]})
        cfg = self.cfg
        s = toks.shape[1]
        n_pg = s // PAGE_SIZE
        positions = jnp.arange(s)
        x = self.model._embed(params, toks)
        new_k, new_v = list(k_pages), list(v_pages)
        for layer in range(len(new_k)):
            j, i = divmod(layer, len(cfg.pattern))
            kind = cfg.pattern[i]
            bp = jax.tree.map(lambda a: a[j],
                              params["blocks"][f"p{i}_{kind}"])

            def mix(q, k, v, layer=layer, kind=kind):
                kpg = k[0].reshape(n_pg, PAGE_SIZE, cfg.num_kv_heads,
                                   cfg.head_dim).astype(KV_DTYPE)
                vpg = v[0].reshape(n_pg, PAGE_SIZE, cfg.num_kv_heads,
                                   cfg.head_dim).astype(KV_DTYPE)
                if self._layer_ring(layer):
                    new_k[layer] = new_k[layer].at[l_ids].set(kpg[l_src])
                    new_v[layer] = new_v[layer].at[l_ids].set(vpg[l_src])
                else:
                    new_k[layer] = new_k[layer].at[g_ids].set(kpg)
                    new_v[layer] = new_v[layer].at[g_ids].set(vpg)
                window = cfg.sliding_window if kind == ATTN_LOCAL else 0
                return attn.sdpa(q, k, v, causal=True, window=window,
                                 q_positions=positions,
                                 k_positions=positions)

            x = self._block_forward(bp, x, positions, mix)
        x = T.apply_norm(cfg, params["ln_f"], x)
        xl = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)
        logits = L.unembed(params["embed"], xl, cfg.logit_softcap)
        return jnp.argmax(logits[0, -1]), new_k, new_v

    def prefill(self, req: Request) -> None:
        """Forward over the prompt, scattering its KV page-by-page into
        the request's granted pages (global page p holds tokens
        [p*PAGE, (p+1)*PAGE); ring layers keep the last ``ring_pages``
        prompt pages at their ring slots).

        Pure-global stacks route through the CHUNKED path when a prefix
        cache is attached (suffix-only prefill + insert) or when the
        prompt exceeds one chunk (fixed-size chunks reuse O(chunk *
        log pool) compile buckets instead of one shape per prompt page
        count -- the PR 4 compile-key follow-up)."""
        assert req.pages or req.local_pages, \
            f"{req.req_id}: prefill before admission"
        cfg = self.cfg
        n_pg = -(-req.prompt_len // PAGE_SIZE)
        if (self.groups.local_layers == 0
                and (self.prefix is not None or n_pg > self.chunk_pages)):
            self._prefill_chunked(req)
            if self.prefix is not None:
                self._prefix_insert(req)
            return
        toks = prompt_for(req, cfg.vocab_size)
        pad = n_pg * PAGE_SIZE - req.prompt_len
        if pad:
            toks = jnp.pad(toks, ((0, 0), (0, pad)))
        if req.pages:
            g_ids = np.asarray(self._phys(req.pages[:n_pg]), np.int32)
        else:                               # pure-local stack: unused
            g_ids = np.full(n_pg, self.trash_page, np.int32)
        if self.use_rings:
            ring = self.groups.ring_pages
            # the last min(ring, n_pg) prompt pages survive, each at ring
            # slot (page % ring) -- consecutive pages hit distinct slots
            lp = self._phys_local(req.local_pages)
            l_src = np.arange(max(0, n_pg - ring), n_pg, dtype=np.int32)
            l_ids = np.asarray([lp[j % ring] for j in l_src], np.int32)
        else:
            l_src = np.zeros(0, np.int32)
            l_ids = np.zeros(0, np.int32)
        nxt, self.store.k_pages, self.store.v_pages = self._prefill(
            self.params, toks, jnp.asarray(req.prompt_len - 1, jnp.int32),
            jnp.asarray(g_ids), jnp.asarray(l_ids), jnp.asarray(l_src),
            self.store.k_pages, self.store.v_pages)
        self.prefill_pages_computed += n_pg
        # zenlint: ignore[ZL004] -- first-token extraction: once per
        # request at prefill, the designed sync point (see DenseRunner).
        self.generated[req.req_id] = [int(nxt)]

    # -- chunked / suffix-only prefill (pure-global stacks) ------------------
    def _chunk_fn(self, params, toks, lead, base, last, g_ids, cow_src,
                  ctx_table, k_pages, v_pages):
        """One prefill chunk: forward over ``toks`` (page-aligned chunk
        starting at absolute position ``base``), scatter its KV into the
        ``g_ids`` pages, and attend over (cached or earlier-chunk)
        context pages named by ``ctx_table`` (-1 padded, width bucketed)
        plus the chunk itself.

        Copy-on-write is FUSED: the first ``lead`` slots of chunk page 0
        are replaced with the cached partial page ``cow_src``'s content
        before scatter+attention, so one donated op yields a private page
        holding cached-lead + computed-suffix, and the attention keys for
        those positions are the true cached KV.  Cold path: lead=0,
        cow_src=trash, all-(-1) context.

        Compile key: (chunk page count, context-table bucket) only --
        lead/base/last/cow_src are traced scalars, so warm and cold
        prefills of any offset share compiles."""
        self.prefill_traces += 1
        t = obs_trace.TRACER
        if t is not None:
            t.instant("compile", "chunk_trace", None,
                      {"backend": "paged", "tokens": toks.shape[1],
                       "ctx_w": ctx_table.shape[0]})
        cfg = self.cfg
        s = toks.shape[1]
        n_pg = s // PAGE_SIZE
        w = ctx_table.shape[0]
        positions = base + jnp.arange(s)
        k_pos = jnp.concatenate([jnp.arange(w * PAGE_SIZE), positions])
        k_valid = jnp.concatenate(
            [jnp.repeat(ctx_table >= 0, PAGE_SIZE),
             jnp.ones(s, bool)])
        lead_mask = (jnp.arange(PAGE_SIZE) < lead)[:, None, None]
        x = self.model._embed(params, toks)
        new_k, new_v = list(k_pages), list(v_pages)
        for layer in range(len(new_k)):
            j, i = divmod(layer, len(cfg.pattern))
            kind = cfg.pattern[i]
            bp = jax.tree.map(lambda a: a[j],
                              params["blocks"][f"p{i}_{kind}"])

            def mix(q, k, v, layer=layer):
                kpg = k[0].reshape(n_pg, PAGE_SIZE, cfg.num_kv_heads,
                                   cfg.head_dim)
                vpg = v[0].reshape(n_pg, PAGE_SIZE, cfg.num_kv_heads,
                                   cfg.head_dim)
                kpg = kpg.at[0].set(jnp.where(
                    lead_mask, new_k[layer][cow_src].astype(k.dtype),
                    kpg[0]))
                vpg = vpg.at[0].set(jnp.where(
                    lead_mask, new_v[layer][cow_src].astype(v.dtype),
                    vpg[0]))
                new_k[layer] = new_k[layer].at[g_ids].set(
                    kpg.astype(KV_DTYPE))
                new_v[layer] = new_v[layer].at[g_ids].set(
                    vpg.astype(KV_DTYPE))
                # context pages are read back AFTER the scatter: they are
                # disjoint from g_ids (strictly earlier absolute pages),
                # so the gather sees cached/earlier-chunk KV only
                ctx_k = new_k[layer][jnp.maximum(ctx_table, 0)].reshape(
                    1, w * PAGE_SIZE, cfg.num_kv_heads,
                    cfg.head_dim).astype(k.dtype)
                ctx_v = new_v[layer][jnp.maximum(ctx_table, 0)].reshape(
                    1, w * PAGE_SIZE, cfg.num_kv_heads,
                    cfg.head_dim).astype(v.dtype)
                k_cat = jnp.concatenate(
                    [ctx_k, kpg.reshape(1, s, cfg.num_kv_heads,
                                        cfg.head_dim)], axis=1)
                v_cat = jnp.concatenate(
                    [ctx_v, vpg.reshape(1, s, cfg.num_kv_heads,
                                        cfg.head_dim)], axis=1)
                return attn.sdpa(q, k_cat, v_cat, causal=True,
                                 q_positions=positions, k_positions=k_pos,
                                 k_valid=k_valid)

            x = self._block_forward(bp, x, positions, mix)
        x = T.apply_norm(cfg, params["ln_f"], x)
        xl = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)
        logits = L.unembed(params["embed"], xl, cfg.logit_softcap)
        return jnp.argmax(logits[0, -1]), new_k, new_v

    def _prefill_chunked(self, req: Request) -> None:
        """Suffix-only prefill in absolute-grid chunks.  The first
        ``req.cached_len`` prompt tokens are already in cache pages
        (``req.shared_pages`` + a COW lead); computation starts at the
        cached page boundary and each chunk ends on a multiple of
        ``chunk_pages`` -- warm and cold runs of the same prompt see
        IDENTICAL chunk boundaries past the cached region, so their
        attention math (and tokens) agree exactly."""
        cfg = self.cfg
        toks = prompt_for(req, cfg.vocab_size)
        total_pg = -(-req.prompt_len // PAGE_SIZE)
        pad = total_pg * PAGE_SIZE - req.prompt_len
        if pad:
            toks = jnp.pad(toks, ((0, 0), (0, pad)))
        cached = req.cached_len
        pages_all = list(req.shared_pages) + self._phys(req.pages)
        assert len(pages_all) >= total_pg, \
            f"{req.req_id}: {len(pages_all)} pages < prompt {total_pg}"
        p = cached // PAGE_SIZE        # == len(req.shared_pages)
        nxt = None
        tr = obs_trace.TRACER
        while p < total_pg:
            n_pg = min(self.chunk_pages - p % self.chunk_pages,
                       total_pg - p)
            s0 = p * PAGE_SIZE
            lead = cached - s0 if s0 < cached else 0
            ctx_w = _next_pow2(max(p, 1))
            ctx = np.full(ctx_w, -1, np.int32)
            ctx[:p] = pages_all[:p]
            g_ids = np.asarray(pages_all[p:p + n_pg], np.int32)
            last = min(req.prompt_len - 1 - s0, n_pg * PAGE_SIZE - 1)
            cow_id = (req.cow_src_page
                      if lead and req.cow_src_page is not None
                      else self.trash_page)
            nxt, self.store.k_pages, self.store.v_pages = self._chunk(
                self.params, toks[:, s0:s0 + n_pg * PAGE_SIZE],
                jnp.asarray(lead, jnp.int32), jnp.asarray(s0, jnp.int32),
                jnp.asarray(last, jnp.int32), jnp.asarray(g_ids),
                jnp.asarray(cow_id, jnp.int32), jnp.asarray(ctx),
                self.store.k_pages, self.store.v_pages)
            self.prefill_pages_computed += n_pg
            if tr is not None:
                tr.instant("request", "prefill_chunk", req.req_id,
                           {"start_page": p, "pages": n_pg, "lead": lead})
            p += n_pg
        if self.prefix is not None and cached % PAGE_SIZE:
            # partial-page hit: the fused lead copy above IS the COW
            self.prefix.stats["cow_copies"] += 1
        self.generated[req.req_id] = [int(nxt)]

    # -- prefix-cache lifecycle ----------------------------------------------
    def _host_prompt(self, req: Request) -> Tuple[int, ...]:
        """The request's prompt token ids as a host tuple (the trie key).
        Synthesized prompts are fetched from device ONCE per request and
        memoized on ``req.prompt_tokens``, which also pins the prompt for
        parking's re-attach lookup."""
        if req.prompt_tokens is None:
            toks = synth_prompt(req.req_id, req.prompt_len,
                                self.cfg.vocab_size)
            req.prompt_tokens = tuple(
                int(t) for t in np.asarray(toks[0]))
        return req.prompt_tokens

    def prefix_attach(self, req: Request) -> None:
        """Pre-admission lookup+pin: match the prompt against the trie,
        pin the chain, and record the shared-page layout on the request
        so the pool charges only the private suffix.  The engine calls
        this right before ``try_admit`` and detaches (pool-side) if
        admission fails."""
        if self.prefix is None or req.prefix_nodes is not None:
            return
        m = self.prefix.pin(self._host_prompt(req),
                            max_len=req.prompt_len - 1)
        req.prefix_nodes = m.nodes
        req.shared_pages = list(m.phys_pages)
        req.cached_len = m.cached_len
        req.cow_src_page = m.cow_src
        t = obs_trace.TRACER
        if t is not None:
            t.instant("request", "prefix_pin", req.req_id,
                      {"cached_len": m.cached_len,
                       "shared_pages": len(m.phys_pages),
                       "cow": m.cow_src is not None})

    def _prefix_insert(self, req: Request) -> None:
        """Post-prefill donation: move the prompt's freshly computed full
        pages out of the view's accounting into the cache (the request
        keeps referencing them, now as pinned shared pages), and donate
        the partial tail page after a self-COW (grant a replacement page,
        copy the tail into it, hand the original to the cache).  A race
        -- another request inserted the same prefix this tick -- adopts
        nothing: probe_new sizes the donation at 0 and this request just
        keeps its private copies."""
        cache = self.prefix
        toks = self._host_prompt(req)
        n_full = req.prompt_len // PAGE_SIZE
        rem = req.prompt_len % PAGE_SIZE
        n_att = len(req.shared_pages)
        pool = self.engine.pool if self.engine is not None else None
        if pool is None or n_att > n_full:
            return
        n_new, partial_new = cache.probe_new(toks, n_att)
        phys: List[int] = []
        if n_new:
            phys = pool.cache_donate(req.pages[:n_new])
            del req.pages[:n_new]
            req.shared_pages.extend(phys)
        partial_phys = None
        if partial_new and rem and n_att + n_new == n_full:
            got = pool.cow_grant()
            if got is not None:
                # after the slice above, the partial tail page is the
                # request's first remaining private page
                src = self._phys(req.pages[:1])[0]
                dst = self._phys(got)[0]
                self._cow_copy(src, dst)
                partial_phys = pool.cache_donate(req.pages[:1])[0]
                req.pages[0] = got[0]
                cache.stats["cow_copies"] += 1
        if phys or partial_phys is not None:
            created = cache.insert(toks, n_att, phys,
                                   partial_page=partial_phys)
            req.prefix_nodes = (req.prefix_nodes or []) + created
            t = obs_trace.TRACER
            if t is not None:
                t.instant("request", "prefix_insert", req.req_id,
                          {"donated": len(phys),
                           "partial": partial_phys is not None})

    def prefix_reattach(self, req: Request) -> bool:
        """Unpark: re-pin the shared prefix chain a parked request was
        decoding through.  The pages may have moved (evicted and
        re-inserted by another tenant) but the token chain is the key,
        so any surviving chain of ``parked_shared`` full nodes is
        content-identical.  False = some node was evicted while parked:
        the caller must requeue the request for a from-scratch recompute."""
        if req.parked_shared == 0:
            return True
        if self.prefix is None:
            return False
        m = self.prefix.pin(self._host_prompt(req),
                            max_full=req.parked_shared)
        if len(m.phys_pages) < req.parked_shared:
            self.reattach_unpins += self.prefix.unpin(m.nodes)
            return False
        req.prefix_nodes = m.nodes
        req.shared_pages = list(m.phys_pages)
        return True

    # -- decode --------------------------------------------------------------
    def _decode_fn(self, params, toks, positions, phys_g, phys_l, off,
                   table_g, table_l, vlen, k_pages, v_pages):
        """One batched decode step over the whole stack (jitted; the page
        arrays are donated so per-layer writes happen in place).  Each
        layer writes at its group's physical page (growing table vs ring)
        and attends through its group's page table."""
        self.decode_traces += 1
        t = obs_trace.TRACER
        if t is not None:
            t.instant("compile", "decode_trace", None,
                      {"backend": "paged", "batch": toks.shape[0],
                       "table_w": table_g.shape[1]})
        cfg = self.cfg
        w = cfg.sliding_window
        new_k, new_v = list(k_pages), list(v_pages)
        x = self.model._embed(params, toks)
        for layer in range(len(new_k)):
            j, i = divmod(layer, len(cfg.pattern))
            kind = cfg.pattern[i]
            bp = jax.tree.map(lambda a: a[j],
                              params["blocks"][f"p{i}_{kind}"])

            def mix(q, k, v, layer=layer, kind=kind):
                ring = self._layer_ring(layer)
                phys = phys_l if ring else phys_g
                kp = new_k[layer].at[phys, off].set(
                    k[:, 0].astype(KV_DTYPE))
                vp = new_v[layer].at[phys, off].set(
                    v[:, 0].astype(KV_DTYPE))
                new_k[layer], new_v[layer] = kp, vp
                o = self._paged_attn(q[:, 0], kp, vp,
                                     table_l if ring else table_g, vlen,
                                     window=w if kind == ATTN_LOCAL else 0,
                                     ring=ring)
                return o[:, None]

            x = self._block_forward(bp, x, positions, mix)
        x = T.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(params["embed"], x, cfg.logit_softcap)
        return jnp.argmax(logits[:, -1], -1), new_k, new_v

    def decode(self, running: List[Request]) -> None:
        if not running:
            return
        b = self.max_batch
        assert len(running) <= b, f"{len(running)} running > max_batch {b}"
        ring = self.groups.ring_pages if self.use_rings else 1
        pos = np.asarray([r.length for r in running])     # write positions
        for r, p in zip(running, pos):
            if ((r.pages or r.shared_pages)
                    and p // PAGE_SIZE >= len(r.shared_pages) + len(r.pages)):
                raise RuntimeError(
                    f"{r.req_id}: token {p} beyond granted pages "
                    f"({len(r.shared_pages)} shared + {len(r.pages)}) -- "
                    "engine must grow with horizon=1")
            if (self.use_rings
                    and (p // PAGE_SIZE) % ring >= len(r.local_pages)):
                raise RuntimeError(
                    f"{r.req_id}: token {p} beyond granted ring pages "
                    f"({len(r.local_pages)}/{ring})")
        # batch is padded to max_batch: idle lanes write into the trash
        # page with an all-masked table, so the compile key is constant
        # in batch size; the table width is bucketed to the next power of
        # two so a growing widest-grant re-buckets O(log pool) times.
        # Tables and write slots carry PHYSICAL ids (requests hold
        # view-local ones): the kernel indexes the possibly pod-shared
        # device arrays, where only physical ids are unique.  A request
        # with a cached prefix mixes BOTH id classes in one table: its
        # read-only shared pages (already physical, cache-owned) lead,
        # its view-translated private pages follow; decode always writes
        # past the prefix, so only private pages are ever written.
        g_phys = [list(r.shared_pages) + self._phys(r.pages)
                  for r in running]
        l_phys = ([self._phys_local(r.local_pages) for r in running]
                  if self.use_rings else [[] for _ in running])
        s = zensan.SAN
        if s is not None:
            # runtime twin of zenlint ZL001: every id entering the
            # table must be this view's grant or a cache page
            s.table(self.engine.pool if self.engine is not None else None,
                    g_phys, l_phys)
        maxp_b = _next_pow2(max(max(len(p) for p in g_phys), 1))
        toks = np.zeros((b, 1), np.int32)
        positions = np.zeros((b, 1), np.int32)
        offs = np.zeros(b, np.int32)
        vlen = np.ones(b, np.int32)
        phys_g = np.full(b, self.trash_page, np.int32)
        phys_l = np.full(b, self.trash_page, np.int32)
        table_g = np.full((b, maxp_b), -1, np.int32)
        table_g[:len(running)] = page_table(running, maxp_b, pages=g_phys)
        table_l = np.full((b, ring), -1, np.int32)
        for i, (r, p) in enumerate(zip(running, pos)):
            toks[i, 0] = self.generated[r.req_id][-1]
            positions[i, 0] = p
            offs[i] = p % PAGE_SIZE
            vlen[i] = p + 1
            if g_phys[i]:
                phys_g[i] = g_phys[i][p // PAGE_SIZE]
            if self.use_rings:
                phys_l[i] = l_phys[i][(p // PAGE_SIZE) % ring]
                table_l[i, :len(l_phys[i])] = l_phys[i]
        nxt, self.store.k_pages, self.store.v_pages = self._decode(
            self.params, jnp.asarray(toks), jnp.asarray(positions),
            jnp.asarray(phys_g), jnp.asarray(phys_l), jnp.asarray(offs),
            jnp.asarray(table_g), jnp.asarray(table_l), jnp.asarray(vlen),
            self.store.k_pages, self.store.v_pages)
        # zenlint: ignore[ZL004] -- THE one batched device->host fetch
        # per decode step (all lanes' tokens in one transfer); every
        # other read below indexes this host copy.
        nxt = np.asarray(nxt)
        for i, req in enumerate(running):
            self.generated[req.req_id].append(int(nxt[i]))

    # -- parking -------------------------------------------------------------
    def park(self, drained):
        """Snapshot ONLY the view's pages: gather each drained request's
        KV to host (per layer group: one (layers, n_pages, PAGE, KV, hd)
        array for the growing tables and one for the rings -- ``drained``
        carries the *physical* ids ``reclaim`` translated before
        freeing).  The pool-sized device arrays are dropped only when no
        co-tenant still decodes through the shared store: an aliased
        tenant's real reclamation is its pages returning to the shared
        free list, where the co-tenants immediately reuse them."""
        state = super().park(drained)
        state["kv"] = self._gather_drained(drained)
        # drop the device arrays unless a co-tenant still decodes through
        # them: a PARKED co-tenant doesn't count (its KV is already
        # snapshotted to host, and unpark revives the arrays), so the
        # last active tenant to park takes the pool's HBM with it
        pool = self.engine.pool if self.engine is not None else None
        own = getattr(pool, "app", None)
        views = getattr(getattr(pool, "shared", None), "views", {})
        sole = all(getattr(views.get(u), "parked", False)
                   for u in self.store.users if u != own)
        if sole:
            # cached prefix pages live inside these arrays: flush them
            # (every pin was dropped when the tenants' requests were
            # reclaimed) so the index doesn't outlive the content
            shared = getattr(pool, "shared", None)
            if shared is not None:
                shared.flush_prefix_caches(self.store.key)
            elif self.prefix is not None:
                self.prefix.flush()
            self.store.drop_arrays()
        state["arrays_dropped"] = sole
        return state

    def unpark(self, state, restored):
        super().unpark(state, restored)
        self.store.ensure_arrays()      # no-op when co-tenants kept them
        self._scatter_restored(state["kv"], restored)

    def _layer_split(self):
        table_layers = [l for l in range(self.num_layers)
                        if not self._layer_ring(l)]
        ring_layers = [l for l in range(self.num_layers)
                       if self._layer_ring(l)]
        return table_layers, ring_layers

    def _gather_drained(self, drained):
        """Host snapshot of each drained request's KV, keyed by request:
        per layer group one (layers, n_pages, PAGE, KV, hd) array for the
        growing tables and one for the rings.  ``drained`` carries the
        *physical* ids ``reclaim`` translated before freeing."""
        table_layers, ring_layers = self._layer_split()

        def gather(layers, ids):
            if not layers or not ids:
                return None
            idx = jnp.asarray(ids, jnp.int32)
            k = np.stack([np.asarray(self.k_pages[l][idx]) for l in layers])
            v = np.stack([np.asarray(self.v_pages[l][idx]) for l in layers])
            return (_to_savable(k), _to_savable(v))

        kv = {}
        for req, (g_ids, l_ids) in drained:
            kv[req.req_id] = {"g": gather(table_layers, g_ids),
                              "l": gather(ring_layers, l_ids)}
        return kv

    def _scatter_restored(self, kv, restored):
        """Write gathered KV back at each restored request's CURRENT
        grants -- ``self._phys`` maps through this runner's own view, so
        the same helper serves unpark (same view, fresh ids) and replica
        migration (target view, same physical arrays)."""
        table_layers, ring_layers = self._layer_split()
        for req in restored:
            saved = kv[req.req_id]
            for layers, ids, packed in ((table_layers, self._phys(req.pages),
                                         saved["g"]),
                                        (ring_layers,
                                         self._phys_local(req.local_pages),
                                         saved["l"])):
                if packed is None:
                    continue
                (ka, kd), (va, vd) = packed
                k = jnp.asarray(_from_saved(ka, kd))   # (L, n, PAGE, KV, hd)
                v = jnp.asarray(_from_saved(va, vd))
                pages = jnp.asarray(ids, jnp.int32)
                for li, layer in enumerate(layers):
                    (self.store.k_pages[layer],
                     self.store.v_pages[layer]) = self._scatter(
                        self.store.k_pages[layer],
                        self.store.v_pages[layer], pages, k[li], v[li])

    can_migrate = True

    def migrate_out(self, drained):
        state = super().migrate_out(drained)
        state["kv"] = self._gather_drained(drained)
        return state

    def migrate_in(self, state, restored):
        super().migrate_in(state, restored)
        self._scatter_restored(state["kv"], restored)


def build_runner(backend: str, cfg: ModelConfig, *, seed: int = 0,
                 max_batch: int = 4, cache_len: int = 256,
                 pool_pages: int = 128, use_rings: bool = True,
                 kv_store: Optional[KVArrayStore] = None,
                 prefix_cache=None, chunk_pages: int = 4) -> ModelRunner:
    """Factory keyed by ``Application.options['backend']``.  ``kv_store``
    aliases the paged backend onto the pod's shared device arrays;
    ``prefix_cache`` attaches the pod's global prefix cache (paged only:
    the dense backend has no page identity to share, so asking for a
    cache there is REJECTED rather than silently dropped -- a benchmark
    must never compare a cached arm against one that quietly never
    cached)."""
    if backend == "dense":
        if prefix_cache is not None:
            raise ValueError(
                "backend='dense' cannot serve prefix_cache=True: the "
                "dense KV cache has no shareable page identity; use "
                "backend='paged' or drop the option")
        return DenseRunner(cfg, seed=seed, max_batch=max_batch,
                           cache_len=cache_len)
    if backend == "paged":
        return PagedRunner(cfg, seed=seed, pool_pages=pool_pages,
                           max_batch=max_batch, use_rings=use_rings,
                           kv_store=kv_store, prefix_cache=prefix_cache,
                           chunk_pages=chunk_pages)
    raise ValueError(f"unknown serving backend {backend!r} "
                     "(expected 'dense' or 'paged')")
