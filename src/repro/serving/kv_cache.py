"""Paged KV cache with history-driven pool sizing.

The serving-side instantiation of the paper's data-component auto-scaling:
a request's KV footprint is *input-dependent* (prompt + generation length),
so per-request allocation follows the §9.3 policy -- an *initial* page grant
plus *incremental* page grants on growth, both solved from the decayed
history of observed request lengths (core/sizing.py).  Pages are the
allocation quantum (the paper's fixed-increment memory regions).

This Python-level pool manages logical pages; the device-side cache is a
dense (pool_pages, page_size, KV, hd) array per layer indexed by page
tables, attended to by the paged-attention kernel (kernels/paged_attention
on TPU, ref path on CPU)."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.history import HistoryStore
from repro.core.sizing import SizingSolution, solve_init_step

PAGE_SIZE = 128  # tokens per page


@dataclass
class Request:
    req_id: str
    prompt_len: int
    max_new_tokens: int
    generated: int = 0
    pages: List[int] = field(default_factory=list)
    state: str = "queued"     # queued|running|done|preempted|rejected|parked
    submitted_at: float = 0.0       # engine-stamped (perf_counter)
    first_token_at: Optional[float] = None

    @property
    def length(self) -> int:
        return self.prompt_len + self.generated

    def pages_needed(self, horizon: int = 0) -> int:
        return -(-(self.length + horizon) // PAGE_SIZE)

    def max_pages(self) -> int:
        """Pages needed at completion (prompt fully decoded)."""
        return -(-(self.prompt_len + self.max_new_tokens) // PAGE_SIZE)


class PagePool:
    """Fixed pool of KV pages; per-request grants follow the sizing policy."""

    def __init__(self, num_pages: int, history: Optional[HistoryStore] = None,
                 app: str = "serve", policy: str = "history",
                 fixed_init_pages: int = 2, fixed_step_pages: int = 1):
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages))
        self.history = history
        self.app = app
        self.policy = policy
        self.fixed = (fixed_init_pages, fixed_step_pages)
        self._sizing: Optional[SizingSolution] = None
        self._solve_counter = 0
        self.stats = {"grants": 0, "grant_pages": 0, "denials": 0,
                      "scaleups": 0, "released": 0}

    # -- sizing policy ------------------------------------------------------
    def sizing(self) -> SizingSolution:
        if self.policy == "fixed":
            return SizingSolution(self.fixed[0], self.fixed[1], 0, 0, 0, True)
        if self._sizing is None or self._solve_counter >= 1000:
            self._solve_counter = 0
            hist = []
            if self.history is not None:
                h = self.history.get(self.app, "request", "pages")
                if h is not None:
                    hist = h.samples()
            if self.policy == "peak":
                peak = max((v for v, _ in hist), default=4.0)
                self._sizing = SizingSolution(peak, 1, peak, 0, 0, True)
            else:
                self._sizing = solve_init_step(hist, quantum=1.0)
        return self._sizing

    # -- physical allocation primitives (overridden by tenancy.PoolView) ----
    def _alloc(self, n: int) -> Optional[List[int]]:
        """Take n physical pages, or None when they can't be granted."""
        if n > len(self.free):
            return None
        return [self.free.pop() for _ in range(n)]

    def _dealloc(self, pages: List[int]) -> None:
        self.free.extend(pages)

    def _page_cap(self) -> int:
        """Hard page ceiling a single request can ever hold."""
        return self.num_pages

    def admissible(self, req: Request) -> bool:
        """False when the request could NEVER complete under this pool's
        hard cap -- no sequence of grows or preemptions can serve it, so
        the engine must reject it instead of retrying forever (counted as
        a permanent denial)."""
        if req.max_pages() <= self._page_cap():
            return True
        self.stats["denials"] += 1
        return False

    # -- allocation ---------------------------------------------------------
    def try_admit(self, req: Request) -> bool:
        """Initial grant: max(prompt pages, policy init)."""
        sz = self.sizing()
        # a policy init larger than the hard cap must not turn a servable
        # request into a permanent denial: clamp, never below actual need
        want = max(req.pages_needed(),
                   min(max(req.pages_needed(), int(sz.init)),
                       self._page_cap()))
        got = self._alloc(want)
        if got is None:
            self.stats["denials"] += 1
            return False
        req.pages = got
        req.state = "running"
        self.stats["grants"] += 1
        self.stats["grant_pages"] += want
        self._solve_counter += 1
        return True

    def grow(self, req: Request, horizon: int = 0) -> bool:
        """Incremental grant when the request outgrows its pages.

        ``horizon`` asks for headroom beyond the current length: the engine
        grows with horizon=1 so the NEXT token's write slot is always backed
        by a physical page (the paged runner scatters into it)."""
        if req.pages_needed(horizon) <= len(req.pages):
            return True
        sz = self.sizing()
        need = req.pages_needed(horizon) - len(req.pages)
        # clamp the policy step to the cap headroom (see try_admit): a
        # too-big step would deny forever what `need` pages would serve
        want = max(need, min(max(int(sz.step), need),
                             self._page_cap() - len(req.pages)))
        got = self._alloc(want)
        if got is None:
            self.stats["denials"] += 1
            return False
        req.pages.extend(got)
        self.stats["scaleups"] += 1
        return True

    def release(self, req: Request) -> None:
        self._dealloc(req.pages)
        self.stats["released"] += 1
        if self.history is not None:
            self.history.observe(self.app, "request", "pages",
                                 max(len(req.pages), 1))
        req.pages = []
        req.state = "done"

    # -- park/unpark (idle reclamation; repro.autoscale.parking) -------------
    def reclaim(self, req: Request) -> List[int]:
        """Return a request's pages WITHOUT completing it: no history
        sample (the request resumes with the same footprint) and no
        'released' count.  Returns the page ids it held, so the drained
        KV can be restored into freshly granted pages on unpark."""
        held, req.pages = req.pages, []
        self._dealloc(held)
        req.state = "parked"
        return held

    def regrant(self, req: Request, n: int) -> bool:
        """Unpark: re-grant exactly the drained page count (the sizing
        policy already spoke when the pages were first granted)."""
        got = self._alloc(n)
        if got is None:
            self.stats["denials"] += 1
            return False
        req.pages = got
        req.state = "running"
        return True

    @property
    def physical_pages(self) -> int:
        """Size of the backing physical pool (the runner's page-array dim)."""
        return self.num_pages

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / max(self.num_pages, 1)


def page_table(requests: Sequence[Request], max_pages: int) -> np.ndarray:
    """(B, max_pages) int32 page table (-1 padded) for the decode kernel."""
    out = np.full((len(requests), max_pages), -1, np.int32)
    for i, r in enumerate(requests):
        n = min(len(r.pages), max_pages)
        out[i, :n] = r.pages[:n]
    return out


def pool_pages_for_budget(hbm_bytes: int, num_layers: int, kv_dim: int,
                          bytes_per: int = 2) -> int:
    """How many pages fit a device-memory budget (both K and V)."""
    per_page = 2 * PAGE_SIZE * kv_dim * bytes_per * num_layers
    return max(int(hbm_bytes // per_page), 1)
