"""Paged KV cache with history-driven pool sizing.

The serving-side instantiation of the paper's data-component auto-scaling:
a request's KV footprint is *input-dependent* (prompt + generation length),
so per-request allocation follows the §9.3 policy -- an *initial* page grant
plus *incremental* page grants on growth, both solved from the decayed
history of observed request lengths (core/sizing.py).  Pages are the
allocation quantum (the paper's fixed-increment memory regions).

This Python-level pool manages logical pages; the device-side cache is a
dense (pool_pages, page_size, KV, hd) array per layer indexed by page
tables, attended to by the paged-attention kernel (kernels/paged_attention
on TPU, ref path on CPU)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis import zensan
from repro.core.history import HistoryStore
from repro.core.sizing import SizingSolution, solve_init_step

PAGE_SIZE = 128  # tokens per page


@dataclass(frozen=True)
class PageGroups:
    """Per-layer-kind page accounting for a mixed global/sliding-window
    stack.

    A *global* attention layer's page table grows with sequence length; a
    *sliding-window* (ATTN_LOCAL) layer only ever needs a fixed ring of
    ``ceil(window/PAGE_SIZE) + 1`` pages -- the ring covers the window
    plus the partially-written page decode is landing in.  The two
    groups index DISJOINT per-layer device arrays, so they are granted
    from independent page-id spaces and charged separately: a
    long-generation request on a gemma3-style 5-local:1-global stack
    holds ``O(length)`` pages on one sixth of its layers and ``O(window)``
    on the rest, instead of ``O(length)`` on all of them.
    """

    global_layers: int              # layers with growing page tables
    local_layers: int               # sliding-window layers (ring pages)
    window: int                     # tokens; > 0 iff local_layers > 0

    @classmethod
    def from_config(cls, cfg) -> "PageGroups":
        """Group split of a ModelConfig's pattern (one pattern repeat)."""
        from repro.configs.base import ATTN_LOCAL
        n_local = sum(1 for k in cfg.pattern if k == ATTN_LOCAL)
        return cls(global_layers=len(cfg.pattern) - n_local,
                   local_layers=n_local,
                   window=cfg.sliding_window if n_local else 0)

    @property
    def ring_pages(self) -> int:
        """Fixed per-request page count of one local layer's ring."""
        if self.local_layers == 0:
            return 0
        return -(-self.window // PAGE_SIZE) + 1

    @property
    def w_global(self) -> float:
        """Fraction of the per-page HBM footprint a global page costs."""
        total = self.global_layers + self.local_layers
        return self.global_layers / max(total, 1)

    @property
    def w_local(self) -> float:
        total = self.global_layers + self.local_layers
        return self.local_layers / max(total, 1)


@dataclass
class Request:
    req_id: str
    prompt_len: int
    max_new_tokens: int
    generated: int = 0
    pages: List[int] = field(default_factory=list)
    state: str = "queued"     # queued|running|done|preempted|rejected|parked
    submitted_at: float = 0.0       # engine-stamped (perf_counter)
    first_token_at: Optional[float] = None
    # sliding-window ring pages (only when the pool has a local group);
    # capped at PageGroups.ring_pages regardless of sequence length
    local_pages: List[int] = field(default_factory=list)
    # completed output (prefill token + decoded tokens); the runner hands
    # ownership back here on completion so its `generated` dict can evict
    output_tokens: Optional[List[int]] = None
    # -- global prefix cache (serving/prefix_cache.py) -----------------------
    # PHYSICAL page ids of the read-only cached-prefix pages this request
    # references (never view-local: cache pages belong to no view).  They
    # form the leading entries of the decode page table, ahead of the
    # view-translated private pages, and are excluded from quota charging.
    shared_pages: List[int] = field(default_factory=list)
    # prompt tokens covered by the cache at attach time (prefill skips them)
    cached_len: int = 0
    # physical page to copy-on-write the partial lead from (cached_len %
    # PAGE_SIZE tokens land in the request's first private page)
    cow_src_page: Optional[int] = None
    # pinned PrefixNode chain; the pool unpins on release/reclaim
    prefix_nodes: Optional[list] = None
    # how many shared pages a parked request must re-pin on unpark
    parked_shared: int = 0
    # explicit prompt (bench/test prompt-overlap control); when None the
    # runner synthesizes from req_id as before
    prompt_tokens: Optional[Tuple[int, ...]] = None

    @property
    def length(self) -> int:
        return self.prompt_len + self.generated

    def pages_needed(self, horizon: int = 0) -> int:
        return -(-(self.length + horizon) // PAGE_SIZE)

    def max_pages(self) -> int:
        """Pages needed at completion (prompt fully decoded)."""
        return -(-(self.prompt_len + self.max_new_tokens) // PAGE_SIZE)

    def local_pages_needed(self, groups: PageGroups,
                           horizon: int = 0) -> int:
        """Ring pages a local layer needs at the current length: grows
        like the global table until the ring is full, then stays put."""
        return min(self.pages_needed(horizon), groups.ring_pages)


class PagePool:
    """Fixed pool of KV pages; per-request grants follow the sizing policy."""

    def __init__(self, num_pages: int, history: Optional[HistoryStore] = None,
                 app: str = "serve", policy: str = "history",
                 fixed_init_pages: int = 2, fixed_step_pages: int = 1,
                 groups: Optional[PageGroups] = None):
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages))
        self.history = history
        self.app = app
        # sizing-history identity: replicas of one app carry distinct view
        # names (``app``) but must read/write ONE per-app history series
        self.history_key = app
        self.policy = policy
        self.fixed = (fixed_init_pages, fixed_step_pages)
        self._sizing: Optional[SizingSolution] = None
        self._solve_counter = 0
        self.stats = {"grants": 0, "grant_pages": 0, "denials": 0,
                      "scaleups": 0, "released": 0, "prefix_unpinned": 0,
                      "prefix_evictions": 0}
        # bound by the executor when the app opts into prefix caching; a
        # private pool owns its cache outright, a PoolView aliases the
        # pod-level one registered on the SharedPagePool
        self.prefix_cache = None
        # per-layer-group accounting (sliding-window rings).  The local
        # group's pages index a DISJOINT set of per-layer device arrays,
        # so they come from their own id space over the same pool size.
        self.groups = None
        self.free_local: Optional[List[int]] = None
        if groups is not None:
            self.set_groups(groups)

    def set_groups(self, groups: Optional[PageGroups]) -> None:
        """Attach (or refresh) the layer-group split.  Must happen while
        no request holds pages -- the id spaces are being (re)defined."""
        self.groups = groups if (groups and groups.local_layers) else None
        self.free_local = (list(range(self._local_space()))
                           if self.groups else None)

    def _local_space(self) -> int:
        """Size of the local-group page-id space (the runner's local
        arrays are pool-sized, like the global ones)."""
        return self.num_pages

    def _ring_pages(self) -> int:
        return self.groups.ring_pages if self.groups else 0

    def _global_need(self, req: Request, horizon: int = 0) -> int:
        """PRIVATE pages the growing (global-group) table needs; zero for
        a stack with no global-KV layers at all.  Prefix-cache shared
        pages already back the leading table entries, so they are not
        charged against the request (or its view quota) again."""
        if self.groups is not None and self.groups.global_layers == 0:
            return 0
        return max(req.pages_needed(horizon) - len(req.shared_pages), 0)

    # -- sizing policy ------------------------------------------------------
    def sizing(self) -> SizingSolution:
        if self.policy == "fixed":
            return SizingSolution(self.fixed[0], self.fixed[1], 0, 0, 0, True)
        if self._sizing is None or self._solve_counter >= 1000:
            self._solve_counter = 0
            hist = []
            if self.history is not None:
                h = self.history.get(self.history_key, "request", "pages")
                if h is not None:
                    hist = h.samples()
            if self.policy == "peak":
                peak = max((v for v, _ in hist), default=4.0)
                self._sizing = SizingSolution(peak, 1, peak, 0, 0, True)
            else:
                self._sizing = solve_init_step(hist, quantum=1.0)
        return self._sizing

    # -- physical allocation primitives (overridden by tenancy.PoolView) ----
    def _alloc(self, n: int) -> Optional[List[int]]:
        """Take n physical pages, or None when they can't be granted.
        Under pool pressure, refcount-0 prefix-cache pages are the first
        victims (LRU) -- cold cached prefixes yield to live requests, but
        pinned nodes are never touched."""
        if n > len(self.free) and self.prefix_cache is not None:
            freed = self.prefix_cache.evict_lru(n - len(self.free))
            self.stats["prefix_evictions"] += freed
        if n > len(self.free):
            return None
        got = [self.free.pop() for _ in range(n)]
        s = zensan.SAN
        if s is not None:
            # a private pool's ids are physical AND request-visible:
            # take+grant collapse into one step (no remap in between)
            s.take(self, got)
            s.grant(self, got, got)
        return got

    def _dealloc(self, pages: List[int]) -> None:
        s = zensan.SAN
        if s is not None:
            s.release(self, pages, pages)
            s.give(self, pages)
        self.free.extend(pages)

    def _give(self, pages: List[int]) -> None:
        """Return PHYSICAL pages straight to the free list -- the
        prefix cache's eviction path (mirrors ``SharedPagePool._give``:
        cache pages were donated out of request accounting, so they
        come back without touching any request/view bookkeeping)."""
        s = zensan.SAN
        if s is not None:
            s.give(self, pages)
        self.free.extend(pages)

    def _alloc_local(self, n: int) -> Optional[List[int]]:
        """Take n local-group (ring) pages from the local id space."""
        if self.free_local is None or n > len(self.free_local):
            return None
        got = [self.free_local.pop() for _ in range(n)]
        s = zensan.SAN
        if s is not None:
            s.grant_local(self, got)
        return got

    def _dealloc_local(self, pages: List[int]) -> None:
        if pages:
            s = zensan.SAN
            if s is not None:
                s.release_local(self, pages)
            self.free_local.extend(pages)

    def _page_cap(self) -> int:
        """Hard page ceiling a single request can ever hold."""
        return self.num_pages

    # -- prefix-cache lifecycle (serving/prefix_cache.py) --------------------
    def cow_grant(self) -> Optional[List[int]]:
        """One page for a copy-on-write split: the caller copies a cached
        page's lead slots here before writing past them.  Returns the
        granted id list (view-local under a PoolView) or None under
        pressure -- a receipt the caller MUST consume (ZL005): dropping
        it either leaks the page or skips the None check."""
        return self._alloc(1)

    def cache_donate(self, ids: Sequence[int]) -> List[int]:
        """Move pages out of request accounting into prefix-cache
        ownership, returning their PHYSICAL ids.  A private pool's ids
        are already physical and the pages simply stay off the free list
        (the cache's free_fn puts them back on eviction); a PoolView
        additionally uncharges its quota and forgets the remap."""
        phys = list(ids)
        s = zensan.SAN
        if s is not None:
            s.cache_donated(self, phys, self.prefix_cache)
        return phys

    def prefix_detach(self, req: Request, keep: bool = False) -> int:
        """Unpin a request's prefix-cache nodes (idempotent).  Returns
        how many nodes dropped to refcount 0, folded into stats.  With
        ``keep`` (the park path) the attach bookkeeping needed for
        unpark re-attachment survives; otherwise the request forgets its
        cached prefix entirely."""
        released = 0
        if req.prefix_nodes and self.prefix_cache is not None:
            released = self.prefix_cache.unpin(req.prefix_nodes)
            self.stats["prefix_unpinned"] += released
        req.prefix_nodes = None
        req.shared_pages = []
        req.cow_src_page = None
        if not keep:
            req.cached_len = 0
            req.parked_shared = 0
        return released

    # -- id translation (overridden by tenancy.PoolView) ---------------------
    def to_physical(self, ids: Sequence[int]) -> List[int]:
        """Physical page ids backing ``ids``.  A private pool's page ids
        ARE physical (they index the runner's own pool-sized arrays), so
        this is the identity; a :class:`~repro.serving.tenancy.PoolView`
        stores *view-local* ids on requests and remaps them here onto
        the pod's shared device arrays."""
        return list(ids)

    def to_physical_local(self, ids: Sequence[int]) -> List[int]:
        """Physical ids of local-group (sliding-window ring) pages."""
        return list(ids)

    def admissible(self, req: Request) -> bool:
        """False when the request could NEVER complete under this pool's
        hard cap -- no sequence of grows or preemptions can serve it, so
        the engine must reject it instead of retrying forever (counted as
        a permanent denial)."""
        need = req.max_pages()
        if self.groups is not None:
            if self.groups.global_layers == 0:
                need = 0
            need = max(need, self._ring_pages())
        if need <= self._page_cap():
            return True
        self.stats["denials"] += 1
        return False

    # -- allocation ---------------------------------------------------------
    def _grant_local(self, req: Request, horizon: int = 0) -> bool:
        """Top the ring grant up to what the current length needs (never
        past the ring).  Rolls back nothing itself -- callers do."""
        if self.groups is None:
            return True
        need = (req.local_pages_needed(self.groups, horizon)
                - len(req.local_pages))
        if need <= 0:
            return True
        got = self._alloc_local(need)
        if got is None:
            return False
        req.local_pages.extend(got)
        return True

    def try_admit(self, req: Request) -> bool:
        """Initial grant: max(prompt pages, policy init) on the global
        table, plus (for sliding-window stacks) the prompt's ring pages."""
        sz = self.sizing()
        if self.groups is not None and self.groups.global_layers == 0:
            want = 0          # pure-local stack: no growing table at all
        else:
            # a policy init larger than the hard cap must not turn a
            # servable request into a permanent denial: clamp, never
            # below actual need
            need = self._global_need(req)
            want = max(need, min(max(need, int(sz.init)), self._page_cap()))
        got = self._alloc(want)
        if got is None:
            self.stats["denials"] += 1
            return False
        req.pages = got
        if not self._grant_local(req):
            req.pages = []
            self._dealloc(got)
            self.stats["denials"] += 1
            return False
        req.state = "running"
        self.stats["grants"] += 1
        self.stats["grant_pages"] += want + len(req.local_pages)
        self._solve_counter += 1
        return True

    def grow(self, req: Request, horizon: int = 0) -> bool:
        """Incremental grant when the request outgrows its pages.

        ``horizon`` asks for headroom beyond the current length: the engine
        grows with horizon=1 so the NEXT token's write slot is always backed
        by a physical page (the paged runner scatters into it).  Layer
        groups grow independently: the global table keeps extending, the
        local ring stops charging once it holds ``ring_pages``."""
        held_local = len(req.local_pages)
        if not self._grant_local(req, horizon):
            self.stats["denials"] += 1
            return False
        if self._global_need(req, horizon) <= len(req.pages):
            return True
        sz = self.sizing()
        need = self._global_need(req, horizon) - len(req.pages)
        # clamp the policy step to the cap headroom (see try_admit): a
        # too-big step would deny forever what `need` pages would serve
        want = max(need, min(max(int(sz.step), need),
                             self._page_cap() - len(req.pages)))
        got = self._alloc(want)
        if got is None:
            grown = req.local_pages[held_local:]
            del req.local_pages[held_local:]
            self._dealloc_local(grown)
            self.stats["denials"] += 1
            return False
        req.pages.extend(got)
        self.stats["scaleups"] += 1
        return True

    def release(self, req: Request) -> None:
        self.prefix_detach(req)
        self._dealloc(req.pages)
        self._dealloc_local(req.local_pages)
        self.stats["released"] += 1
        if self.history is not None:
            self.history.observe(self.history_key, "request", "pages",
                                 max(len(req.pages), 1))
        req.pages = []
        req.local_pages = []
        req.state = "done"

    # -- park/unpark (idle reclamation; repro.autoscale.parking) -------------
    def reclaim(self, req: Request) -> Tuple[List[int], List[int]]:
        """Return a request's pages WITHOUT completing it: no history
        sample (the request resumes with the same footprint) and no
        'released' count.  Returns the *physical* (global, local-ring)
        page ids it held -- translated BEFORE the ids are freed, because
        a PoolView forgets the remap on dealloc -- so the drained KV can
        be gathered off-device and restored into freshly granted pages
        on unpark."""
        held, req.pages = req.pages, []
        held_local, req.local_pages = req.local_pages, []
        phys = self.to_physical(held)
        phys_local = self.to_physical_local(held_local)
        self._dealloc(held)
        self._dealloc_local(held_local)
        # the park snapshot covers ONLY private pages: shared prefix pages
        # are unpinned here (they may be evicted while parked) and unpark
        # re-pins the same token chain -- or recomputes if it was evicted
        req.parked_shared = len(req.shared_pages)
        self.prefix_detach(req, keep=True)
        req.state = "parked"
        s = zensan.SAN
        if s is not None:
            s.parked(self, req.req_id, len(phys), len(phys_local))
        return phys, phys_local

    def regrant(self, req: Request, n: int, n_local: int = 0) -> bool:
        """Unpark: re-grant exactly the drained page counts (the sizing
        policy already spoke when the pages were first granted)."""
        got = self._alloc(n)
        if got is None:
            self.stats["denials"] += 1
            return False
        got_local: List[int] = []
        if n_local:
            got_local = self._alloc_local(n_local)
            if got_local is None:
                self._dealloc(got)
                self.stats["denials"] += 1
                return False
        req.pages = got
        req.local_pages = got_local
        req.state = "running"
        s = zensan.SAN
        if s is not None:
            s.regranted(self, req.req_id, n, n_local)
        return True

    @property
    def physical_pages(self) -> int:
        """Size of the backing physical pool (the runner's page-array dim)."""
        return self.num_pages

    @property
    def utilization(self) -> float:
        """Fraction of the pool's page-layer slots in use.  Without layer
        groups this is plain used/total; with groups each group's usage
        is weighted by the fraction of layers its pages actually occupy,
        so a sliding-window stack's bounded rings show up as the lower
        footprint they are."""
        used_g = self.num_pages - len(self.free)
        if self.groups is None:
            return used_g / max(self.num_pages, 1)
        used_l = self._local_space() - len(self.free_local)
        return ((self.groups.w_global * used_g
                 + self.groups.w_local * used_l)
                / max(self.num_pages, 1))


def page_table(requests: Sequence[Request], max_pages: int,
               pages: Optional[Sequence[Sequence[int]]] = None) -> np.ndarray:
    """(B, max_pages) int32 page table (-1 padded) for the decode kernel.

    ``pages`` overrides each request's id list -- the paged runner passes
    the *physical* ids (``pool.to_physical``) here, since the kernel
    indexes the device page arrays while requests carry view-local ids."""
    out = np.full((len(requests), max_pages), -1, np.int32)
    for i, r in enumerate(requests):
        ids = r.pages if pages is None else pages[i]
        n = min(len(ids), max_pages)
        out[i, :n] = ids[:n]
    return out


def pool_pages_for_budget(hbm_bytes: int, num_layers: int, kv_dim: int,
                          bytes_per: int = 2) -> int:
    """How many pages fit a device-memory budget (both K and V)."""
    per_page = 2 * PAGE_SIZE * kv_dim * bytes_per * num_layers
    return max(int(hbm_bytes // per_page), 1)
