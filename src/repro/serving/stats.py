"""One stats surface for serving: :class:`StatsView`.

Every consumer of serving telemetry -- the autoscale control plane,
``repro.obs`` windowed histograms, benchmarks, and the legacy
``AppHandle.serving_stats`` -- reads through one object with two
explicit temporal modes:

* ``cumulative()`` -- lifetime counters + current gauges, aggregated
  across the app's replicas (engine counters summed, including retired
  replicas so the totals stay monotonic across scale-down; queue depth
  = router queue + every engine queue; latency histograms merged
  across replica lanes).  The per-replica breakdown rides under a
  ``replicas`` key and the router's own counters under ``router``.
* ``windowed(since)`` -- counters as the delta accumulated since a
  ``cumulative()`` marker, gauges as-of-now: the rate view autoscale
  policies consume.  Windowed results are tagged ``windowed=True`` and
  refused as markers (deltas of deltas are garbage).

The dict layout is ``serving_stats()``-compatible: single-replica apps
produce exactly the keys (and values) they always did, plus the two new
sub-dicts.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.obs import metrics as obs_metrics
from repro.obs.metrics import hist_merge
from repro.serving.engine import EngineStats


def aggregate_engine_stats(handle) -> EngineStats:
    """Engine counters summed across an app's replicas -- including
    replicas already retired by scale-down, so totals stay monotonic.
    ``wall_s`` is a gauge: the max across live replicas rides along."""
    eng = handle.engine
    rset = handle.exec_state.get("replicas")
    reps = list(rset.replicas) if rset is not None else []
    engines = [r.engine for r in reps] or ([eng] if eng is not None else [])
    agg = EngineStats()
    for e in engines:
        for f in EngineStats.COUNTERS:
            setattr(agg, f, getattr(agg, f) + getattr(e.stats, f))
        agg.wall_s = max(agg.wall_s, e.stats.wall_s)
    if rset is not None:
        for f in EngineStats.COUNTERS:
            setattr(agg, f, getattr(agg, f) + getattr(rset.retired, f))
    return agg


class StatsView:
    """Cumulative | windowed serving stats for one application."""

    def __init__(self, handle):
        self.handle = handle

    # -- markers -------------------------------------------------------------
    def mark(self) -> Dict:
        """A raw snapshot usable as ``windowed(since=...)`` marker."""
        return self.cumulative()

    # -- temporal modes ------------------------------------------------------
    def cumulative(self) -> Dict:
        h = self.handle
        eng = h.engine
        if eng is None:
            return {}
        rset = h.exec_state.get("replicas")
        reps = list(rset.replicas) if rset is not None else []
        engines = [r.engine for r in reps] or [eng]
        # replicas removed by scale-down took their engines with them;
        # aggregate_engine_stats folds the set's retired tally back in
        out = aggregate_engine_stats(h).as_dict()
        out["queue_len"] = sum(len(e.queue) for e in engines)
        out["num_running"] = sum(len(e.running) for e in engines)
        if rset is not None and rset.router is not None:
            out["queue_len"] += rset.router.queue_len(h.app.name)
        out["parked"] = h.parked

        pools = [e.pool for e in engines]
        pool_counters: Dict[str, int] = {}
        for p in pools:
            for k, v in p.stats.items():
                pool_counters[k] = pool_counters.get(k, 0) + v
        out["pool"] = pool_counters
        used = sum(getattr(p, "used", p.num_pages - len(p.free))
                   for p in pools)
        quota = sum(p.num_pages for p in pools)
        if len(pools) == 1:
            out["pool_utilization"] = pools[0].utilization
        else:
            out["pool_utilization"] = used / max(quota, 1)
        out["pool_quota_pages"] = quota
        out["pool_used_pages"] = used
        if getattr(pools[0], "groups", None) is not None:
            # sliding-window stacks: ring (local-group) pages are charged
            # separately from the growing tables (see PageGroups)
            out["pool_used_local_pages"] = sum(
                getattr(p, "used_local", p._local_space() - len(p.free_local))
                for p in pools)

        runners = [r.runner for r in reps if r.runner is not None] or (
            [h.runner] if h.runner is not None else [])
        runner = runners[0] if runners else None
        if runner is not None and getattr(runner, "store", None) is not None:
            # live device bytes of this app's KV arrays (gauge).  Replicas
            # AND aliased same-shape tenants report the SAME store: one
            # read, never a sum (the pod-level total is
            # shared_pool.kv_device_bytes below).
            out["kv_device_bytes"] = runner.store.device_bytes()
            out["kv_aliased"] = bool(getattr(runner, "shared_kv", False))
            out["kv_store_key"] = runner.store.key
        if runner is not None and hasattr(runner, "prefill_pages_computed"):
            # pages actually computed by prefill (cache hits subtract):
            # the fig_prefix bench's savings numerator, so it must exist
            # on the no-cache arm too
            out["prefill_pages_computed"] = sum(
                r.prefill_pages_computed for r in runners
                if hasattr(r, "prefill_pages_computed"))
        cache = getattr(runner, "prefix", None)
        if cache is not None:
            # global prefix cache: lifetime counters plus the two gauges
            # the fig_prefix bench gates on.  shared_pages counts cache-
            # owned PHYSICAL pages -- excluded from every view's quota but
            # still inside the pod's used_pages (they are not free).
            out["prefix"] = dict(cache.stats)
            out["prefix_lookups"] = cache.stats["lookups"]
            out["prefix_hits"] = cache.stats["hits"]
            out["prefix_hit_rate"] = cache.hit_rate
            out["cow_copies"] = cache.stats["cow_copies"]
            out["shared_pages"] = cache.num_pages

        shared = getattr(pools[0], "shared", None)
        if shared is not None:
            out["shared_pool"] = {
                "num_pages": shared.num_pages,
                "used_pages": shared.used_pages,
                "utilization": shared.utilization,
                "denials_by_app": dict(shared.stats["denials"]),
                "preemptions_by_app": dict(shared.stats["preemptions"]),
                "cross_app_preemptions":
                    shared.stats["cross_app_preemptions"],
                "kv_device_bytes": shared.kv_device_bytes(),
            }

        m = obs_metrics.METRICS
        if m is not None:
            # latency histograms: each replica engine observes into its
            # own lane (app / app@rN); merge same-name histograms so the
            # windowed deltas see ONE monotonic series per metric
            by_name: Dict[str, List[Dict]] = {}
            for e in engines:
                lane = getattr(e, "_obs_app", None) or h.app.name
                for name, hd in m.app_histograms(lane).items():
                    by_name.setdefault(name, []).append(hd)
            if by_name:
                out["hist"] = {name: (ds[0] if len(ds) == 1
                                      else hist_merge(ds))
                               for name, ds in by_name.items()}

        if rset is not None:
            if rset.router is not None:
                out["router"] = rset.router.stats(h.app.name)
            out["replicas"] = [
                {"replica": r.idx,
                 "view": getattr(r.engine.pool, "app", h.app.name),
                 "queue_len": len(r.engine.queue),
                 "num_running": len(r.engine.running),
                 "max_batch": r.engine.max_batch,
                 **{f: getattr(r.engine.stats, f)
                    for f in EngineStats.COUNTERS}}
                for r in reps]
        out["windowed"] = False
        return out

    def windowed(self, since: Dict) -> Dict:
        """Counters since the ``since`` marker; gauges as-of-now."""
        if since.get("windowed"):
            raise ValueError(
                "windowed(since=...) needs a RAW snapshot (from "
                "cumulative()/mark()), not a windowed result: deltas of "
                "deltas are garbage")
        from repro.autoscale.metrics import stats_delta
        out = stats_delta(self.cumulative(), since)
        out["windowed"] = True
        return out
