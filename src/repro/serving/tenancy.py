"""Multi-tenant KV-page sharing: one physical pool per pod, many apps.

The paper's resource-centric claim (§9.3) is that co-located applications
share a pod's memory through history-driven per-request grants instead of
each bringing a peak-provisioned private pool.  This module is the serving
instantiation of that claim:

* :class:`SharedPagePool` -- the single physical page pool of one pod.
  It owns the free list, tracks per-app usage, and arbitrates *cross-app*
  preemption: when any tenant is out of pages, the victim is taken from
  the application furthest over its weighted fair share (not merely the
  requester's own newest request).
* :class:`PoolView` -- one application's window onto the shared pool.  It
  IS a :class:`~repro.serving.kv_cache.PagePool` as far as the
  :class:`~repro.serving.engine.ServingEngine` is concerned (same
  try_admit / grow / release / sizing surface, per-app history-driven
  grant policy), but physical pages come from the shared pool and are
  capped by the view's quota.

Quotas: ``quota`` may be an explicit page count (hard cap), the string
``"fair"`` (dynamic weighted fair share, recomputed as tenants come and
go), or None (work-conserving: an idle pool may be fully consumed by one
tenant; the fair-share preemption policy claws pages back under
contention).  Per-request grant sizes remain history-driven per app via
the §9.3 sizing program, keyed by the view's app name.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Union

from repro.core.history import HistoryStore
from repro.serving.kv_cache import PageGroups, PagePool


class SharedPagePool:
    """One pod's physical KV page pool, shared by N serving applications."""

    def __init__(self, num_pages: int,
                 history: Optional[HistoryStore] = None):
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages))
        self.history = history
        self.views: Dict[str, "PoolView"] = {}
        self.stats = {"preemptions": {}, "cross_app_preemptions": 0,
                      "denials": {}}

    # -- tenancy ------------------------------------------------------------
    def view(self, app: str, *,
             quota: Union[int, str, None] = None, weight: float = 1.0,
             policy: str = "history", fixed_init_pages: int = 2,
             fixed_step_pages: int = 1,
             groups: Optional[PageGroups] = None) -> "PoolView":
        """The (single) view of one application; app names must be unique
        per pod -- a live duplicate would merge two engines' page
        accounting onto one quota and corrupt victim selection."""
        v = self.views.get(app)
        if v is not None:
            if v.engine is not None:
                raise ValueError(
                    f"serve application {app!r} is already live on this "
                    "pod's shared pool: give each serve Application a "
                    "unique name=")
            if groups is not None:
                v.set_groups(groups)
            return v
        v = PoolView(self, app, quota=quota, weight=weight,
                     policy=policy, fixed_init_pages=fixed_init_pages,
                     fixed_step_pages=fixed_step_pages, groups=groups)
        self.views[app] = v
        return v

    def _take(self, n: int) -> Optional[List[int]]:
        if n > len(self.free):
            return None
        return [self.free.pop() for _ in range(n)]

    def _give(self, pages: List[int]) -> None:
        self.free.extend(pages)

    # -- accounting ---------------------------------------------------------
    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self.free)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / max(self.num_pages, 1)

    def fair_share(self, view: "PoolView") -> float:
        """Parked views drop out of the share computation: a parked app
        holds no pages and must not dilute active tenants' shares."""
        if view.parked:
            return 0.0
        total = sum(v.weight for v in self.views.values()
                    if not v.parked) or 1.0
        return self.num_pages * view.weight / total

    # -- cross-app preemption (the tenancy policy) --------------------------
    def select_victim_view(self) -> Optional["PoolView"]:
        """The app furthest over its weighted fair share that still has a
        running request to give back."""
        best, best_over = None, None
        for v in self.views.values():
            if v.parked or v.engine is None or not v.engine.running:
                continue
            over = v.used - self.fair_share(v)
            if best_over is None or over > best_over:
                best, best_over = v, over
        return best

    def preempt_for(self, requester: "PoolView") -> bool:
        """Free pages for ``requester`` by preempting the newest request of
        the most over-share app (possibly the requester itself).  Returns
        True when a preemption happened."""
        victim_view = self.select_victim_view()
        if victim_view is None:
            return False
        if not victim_view.engine.preempt_newest():
            return False
        p = self.stats["preemptions"]
        p[victim_view.app] = p.get(victim_view.app, 0) + 1
        if victim_view is not requester:
            self.stats["cross_app_preemptions"] += 1
        return True


class PoolView(PagePool):
    """One application's quota-capped window onto a :class:`SharedPagePool`.

    Engine-compatible: grants and releases go through the PagePool logic
    (history-driven sizing per app), but the physical free list belongs to
    the shared pool and allocation is denied beyond this view's quota.
    """

    def __init__(self, shared: SharedPagePool, app: str, *,
                 quota: Union[int, str, None] = None, weight: float = 1.0,
                 policy: str = "history", fixed_init_pages: int = 2,
                 fixed_step_pages: int = 1,
                 groups: Optional[PageGroups] = None):
        super().__init__(0, history=shared.history, app=app, policy=policy,
                         fixed_init_pages=fixed_init_pages,
                         fixed_step_pages=fixed_step_pages)
        self.shared = shared
        self.weight = float(weight)
        self._quota = quota
        self.used = 0
        self.used_local = 0
        self.engine = None              # set by ServingEngine.attach
        self.parked = False             # set by repro.autoscale.parking
        self.free = []                  # unused: physical list is shared
        self._denial_cause = "physical"
        if groups is not None:
            self.set_groups(groups)

    def _local_space(self) -> int:
        # the local (ring) id space indexes the app's OWN pool-sized
        # per-layer arrays; its size is the pod pool's physical size, not
        # this view's (dynamic) quota
        return self.shared.num_pages

    # -- quota --------------------------------------------------------------
    @property
    def quota(self) -> int:
        """Effective hard cap in pages for this app."""
        if self._quota is None:
            return self.shared.num_pages          # work-conserving
        if self._quota == "fair":
            return max(int(self.shared.fair_share(self)), 1)
        return int(self._quota)

    def _page_cap(self) -> int:
        return min(self.quota, self.shared.num_pages)

    def resize_quota(self, quota: Union[int, str, None]) -> int:
        """Runtime quota change (the autoscale rebalancer's lever).

        Shrinking below current usage drains the overage through the
        engine's normal preemption path -- preempted requests release
        their pages to the shared pool and re-queue (at-least-once), so
        pages are never stranded on an over-quota view.  Returns the
        number of requests preempted by the shrink."""
        self._quota = quota
        preempted = 0
        while self.used > self.quota or self.used_local > self.quota:
            if self.engine is None or not self.engine.preempt_newest():
                break          # no running request left to give back
            preempted += 1
        return preempted

    def admissible(self, req) -> bool:
        ok = super().admissible(req)
        if not ok:
            self._note_denial()
        return ok

    # -- physical allocation via the shared pool ----------------------------
    def _alloc(self, n: int) -> Optional[List[int]]:
        if self.used + n > self.quota:
            self._denial_cause = "quota"
            self._note_denial()
            return None
        got = self.shared._take(n)
        if got is None:
            self._denial_cause = "physical"
            self._note_denial()
            return None
        self.used += n
        return got

    def _dealloc(self, pages: List[int]) -> None:
        self.used -= len(pages)
        self.shared._give(pages)

    def _alloc_local(self, n: int) -> Optional[List[int]]:
        """Ring pages come from the view's OWN id space (they index the
        app's private per-layer arrays, not the pod-shared global ones)
        but still count against this view's quota: the quota caps each
        layer group's table independently."""
        if self.free_local is None:
            return None
        if self.used_local + n > self.quota:
            self._denial_cause = "quota"
            self._note_denial()
            return None
        if n > len(self.free_local):
            self._denial_cause = "physical"
            self._note_denial()
            return None
        self.used_local += n
        return [self.free_local.pop() for _ in range(n)]

    def _dealloc_local(self, pages: List[int]) -> None:
        if pages:
            self.used_local -= len(pages)
            self.free_local.extend(pages)

    def _note_denial(self) -> None:
        d = self.shared.stats["denials"]
        d[self.app] = d.get(self.app, 0) + 1

    # -- engine hooks --------------------------------------------------------
    def attach(self, engine) -> None:
        self.engine = engine

    def preempt_any(self) -> bool:
        """Engine pressure hook.  A *physical* shortage is arbitrated
        across ALL of the pod's apps (fair-share victim selection); a
        *quota* denial can never be lifted by freeing co-tenants' pages,
        so the app sheds its own load instead of punishing neighbours."""
        if self._denial_cause == "quota":
            return self.engine is not None and self.engine.preempt_newest()
        return self.shared.preempt_for(self)

    def close(self) -> None:
        """Detach this app from the pod pool (on application release)."""
        self.engine = None
        self.shared.views.pop(self.app, None)

    # -- accounting ---------------------------------------------------------
    @property
    def num_pages(self) -> int:          # engine/pretty-print compatibility
        return self.quota

    @num_pages.setter
    def num_pages(self, v: int) -> None:
        pass                             # base __init__ assigns; quota rules

    @property
    def physical_pages(self) -> int:
        return self.shared.num_pages

    @property
    def utilization(self) -> float:
        if self.groups is None:
            return self.used / max(self.quota, 1)
        return ((self.groups.w_global * self.used
                 + self.groups.w_local * self.used_local)
                / max(self.quota, 1))
