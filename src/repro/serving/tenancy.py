"""Multi-tenant KV-page sharing: one physical pool per pod, many apps.

The paper's resource-centric claim (§9.3) is that co-located applications
share a pod's memory through history-driven per-request grants instead of
each bringing a peak-provisioned private pool.  This module is the serving
instantiation of that claim:

* :class:`SharedPagePool` -- the single physical page pool of one pod.
  It owns the free list, tracks per-app usage, and arbitrates *cross-app*
  preemption: when any tenant is out of pages, the victim is taken from
  the application furthest over its weighted fair share (not merely the
  requester's own newest request).
* :class:`PoolView` -- one application's window onto the shared pool.  It
  IS a :class:`~repro.serving.kv_cache.PagePool` as far as the
  :class:`~repro.serving.engine.ServingEngine` is concerned (same
  try_admit / grow / release / sizing surface, per-app history-driven
  grant policy), but physical pages come from the shared pool and are
  capped by the view's quota.

Physical aliasing: requests on a PoolView carry *view-local* page ids;
the view owns a logical->physical remap onto ids drawn from the shared
free list.  Same-KV-shape paged tenants bind one
:class:`~repro.serving.model_runner.KVArrayStore` (registered here per
shape key) and so read/write the pod's ONE device page-array set --
preemption, ``resize_quota`` shrink, and parking move *real* pages
between applications, not just accounting.  The remap is also the
isolation boundary: translating an id the view no longer owns raises,
so no tenant can read a page that was reclaimed from it.

Quotas: ``quota`` may be an explicit page count (hard cap), the string
``"fair"`` (dynamic weighted fair share, recomputed as tenants come and
go), or None (work-conserving: an idle pool may be fully consumed by one
tenant; the fair-share preemption policy claws pages back under
contention).  Per-request grant sizes remain history-driven per app via
the §9.3 sizing program, keyed by the view's app name.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.analysis import zensan
from repro.core.history import HistoryStore
from repro.obs import trace as obs_trace
from repro.serving.kv_cache import PageGroups, PagePool


class SharedPagePool:
    """One pod's physical KV page pool, shared by N serving applications."""

    def __init__(self, num_pages: int,
                 history: Optional[HistoryStore] = None):
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages))
        self.history = history
        self.views: Dict[str, "PoolView"] = {}
        self.stats = {"preemptions": {}, "cross_app_preemptions": 0,
                      "denials": {}, "prefix_evictions": 0}
        # physical KV device-array sets, one per KV shape signature: every
        # same-shape paged tenant aliases the same arrays (see kv_store)
        self.kv_stores: Dict[Tuple, object] = {}
        # global prefix caches, keyed (kv_shape_key, model, seed): tenants
        # may share cached prefix pages only when they share BOTH the
        # device arrays and the weights that produced the KV
        self.prefix_caches: Dict[Tuple, object] = {}

    # -- tenancy ------------------------------------------------------------
    def view(self, app: str, *,
             quota: Union[int, str, None] = None, weight: float = 1.0,
             policy: str = "history", fixed_init_pages: int = 2,
             fixed_step_pages: int = 1,
             groups: Optional[PageGroups] = None,
             history_key: Optional[str] = None) -> "PoolView":
        """The (single) view of one application; app names must be unique
        per pod -- a live duplicate would merge two engines' page
        accounting onto one quota and corrupt victim selection.  Replica
        views of one app carry suffixed names (``app@rN``) but pass the
        bare app name as ``history_key`` so sizing history stays one
        per-application series."""
        v = self.views.get(app)
        if v is not None:
            if v.engine is not None:
                raise ValueError(
                    f"serve application {app!r} is already live on this "
                    "pod's shared pool: give each serve Application a "
                    "unique name=")
            if groups is not None:
                v.set_groups(groups)
            return v
        v = PoolView(self, app, quota=quota, weight=weight,
                     policy=policy, fixed_init_pages=fixed_init_pages,
                     fixed_step_pages=fixed_step_pages, groups=groups)
        if history_key is not None:
            v.history_key = history_key
        self.views[app] = v
        return v

    def _take(self, n: int) -> Optional[List[int]]:
        if n > len(self.free):
            # pool pressure: evict refcount-0 prefix-cache pages (global
            # LRU across every cache on this pod) before denying.  Pinned
            # nodes -- prefixes some in-flight request decodes through --
            # are never victims; live requests always outrank cold cache.
            self._evict_prefix(n - len(self.free))
        if n > len(self.free):
            return None
        got = [self.free.pop() for _ in range(n)]
        s = zensan.SAN
        if s is not None:
            s.take(self, got)
        return got

    def _give(self, pages: List[int]) -> None:
        s = zensan.SAN
        if s is not None:
            s.give(self, pages)
        self.free.extend(pages)

    def _evict_prefix(self, need: int) -> int:
        """Evict up to ``need`` refcount-0 cached pages, oldest first
        across all of the pod's prefix caches; freed pages land back on
        ``self.free`` via each cache's free_fn (:meth:`_give`)."""
        freed = 0
        while freed < need:
            best = None
            for c in self.prefix_caches.values():
                n = c.peek_evictable()
                if n is not None and (best is None
                                      or n.last_used < best[1].last_used):
                    best = (c, n)
            if best is None:
                break
            freed += len(best[0].evict(best[1]))
        self.stats["prefix_evictions"] += freed
        if freed:
            t = obs_trace.TRACER
            if t is not None:
                t.instant("pool", "evict", None,
                          {"pages": freed, "kind": "prefix"})
        return freed

    # -- physical KV device arrays (same-shape tenant aliasing) --------------
    def kv_store(self, key: Tuple, factory: Callable[[], object]) -> object:
        """The pod's single physical KV array set for ``key`` (a KV shape
        signature -- see :func:`repro.serving.model_runner.kv_shape_key`).
        Created by ``factory`` on the first same-shape paged tenant and
        aliased by every later one; dropped when the last aliasing view
        closes.  Tenants whose shape has no registered twin simply get a
        fresh store: mismatched-shape tenants therefore never alias."""
        st = self.kv_stores.get(key)
        if st is None:
            st = factory()
            self.kv_stores[key] = st
        return st

    # -- global prefix caches (serving/prefix_cache.py) ----------------------
    def prefix_cache(self, key: Tuple, factory: Callable[[], object]):
        """The pod's single prefix cache for ``key`` -- ``(kv_shape_key,
        model_name, seed)``: cached KV is a function of the weights, not
        just the array shapes, so only true model twins may share.
        Created on first use; survives app churn (a future same-key
        tenant re-warms instantly) but is flushed with its KV store."""
        c = self.prefix_caches.get(key)
        if c is None:
            c = factory()
            self.prefix_caches[key] = c
        return c

    def flush_prefix_caches(self, kv_key: Tuple) -> int:
        """Evict every unpinned node of the caches bound to KV store
        ``kv_key`` -- called when the store's device arrays go away
        (last tenant closed, or all tenants parked): the cached pages'
        contents die with the arrays, so the index must not outlive
        them.  Returns pages freed."""
        freed = 0
        for key in [k for k in self.prefix_caches
                    if k and k[0] == kv_key]:
            cache = self.prefix_caches[key]
            freed += cache.flush()
            if cache.num_pages == 0:
                self.prefix_caches.pop(key, None)
        return freed

    def kv_device_bytes(self) -> int:
        """Live device bytes of every registered KV array store (the pod's
        REAL paged-KV HBM footprint, as opposed to the accounted pages)."""
        return sum(int(st.device_bytes()) for st in self.kv_stores.values())

    # -- accounting ---------------------------------------------------------
    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self.free)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / max(self.num_pages, 1)

    def fair_share(self, view: "PoolView") -> float:
        """Parked views drop out of the share computation: a parked app
        holds no pages and must not dilute active tenants' shares."""
        if view.parked:
            return 0.0
        total = sum(v.weight for v in self.views.values()
                    if not v.parked) or 1.0
        return self.num_pages * view.weight / total

    # -- cross-app preemption (the tenancy policy) --------------------------
    def select_victim_view(self) -> Optional["PoolView"]:
        """The app furthest over its weighted fair share that still has a
        running request to give back."""
        best, best_over = None, None
        for v in self.views.values():
            if v.parked or v.engine is None or not v.engine.running:
                continue
            over = v.used - self.fair_share(v)
            if best_over is None or over > best_over:
                best, best_over = v, over
        return best

    def preempt_for(self, requester: "PoolView") -> bool:
        """Free pages for ``requester`` by preempting the newest request of
        the most over-share app (possibly the requester itself).  Returns
        True when a preemption happened."""
        victim_view = self.select_victim_view()
        if victim_view is None:
            return False
        if not victim_view.engine.preempt_newest():
            return False
        p = self.stats["preemptions"]
        p[victim_view.app] = p.get(victim_view.app, 0) + 1
        if victim_view is not requester:
            self.stats["cross_app_preemptions"] += 1
        t = obs_trace.TRACER
        if t is not None:
            t.instant("pool", "preempt_cross", requester.app,
                      {"victim": victim_view.app,
                       "cross": victim_view is not requester})
        return True


class PoolView(PagePool):
    """One application's quota-capped window onto a :class:`SharedPagePool`.

    Engine-compatible: grants and releases go through the PagePool logic
    (history-driven sizing per app), but the physical free list belongs to
    the shared pool and allocation is denied beyond this view's quota.

    Requests on a view hold **view-local** page ids; ``_remap`` (and
    ``_remap_local`` for sliding-window rings) translates them to the
    physical ids actually drawn from the shared free list.  The paged
    runner calls :meth:`to_physical` at kernel time, so when same-shape
    tenants alias one :class:`KVArrayStore` the device page arrays are
    indexed by pod-unique physical ids while each app's page tables stay
    in its own id space.  Translation of an id the view no longer owns
    raises -- the isolation guard preemption and quota shrink rely on.
    """

    def __init__(self, shared: SharedPagePool, app: str, *,
                 quota: Union[int, str, None] = None, weight: float = 1.0,
                 policy: str = "history", fixed_init_pages: int = 2,
                 fixed_step_pages: int = 1,
                 groups: Optional[PageGroups] = None):
        super().__init__(0, history=shared.history, app=app, policy=policy,
                         fixed_init_pages=fixed_init_pages,
                         fixed_step_pages=fixed_step_pages)
        self.shared = shared
        self.weight = float(weight)
        self._quota = quota
        self.used = 0
        self.used_local = 0
        self.engine = None              # set by ServingEngine.attach
        self.parked = False             # set by repro.autoscale.parking
        self.free = []                  # unused: physical list is shared
        self._denial_cause = "physical"
        # view-local id space: requests see small stable ids, the view
        # remembers which physical page backs each (recycled on dealloc)
        self.kv_store = None            # bound via bind_kv_store (aliasing)
        self._remap: Dict[int, int] = {}
        self._remap_local: Dict[int, int] = {}
        self._free_ids: List[int] = []
        self._free_ids_local: List[int] = []
        self._next_id = 0
        self._next_id_local = 0
        if groups is not None:
            self.set_groups(groups)

    def _local_space(self) -> int:
        # the local (ring) physical space indexes pool-sized per-layer
        # arrays (shared store's or the app's own); its size is the pod
        # pool's physical size, not this view's (dynamic) quota
        return self.shared.num_pages

    # -- quota --------------------------------------------------------------
    @property
    def quota(self) -> int:
        """Effective hard cap in pages for this app."""
        if self._quota is None:
            return self.shared.num_pages          # work-conserving
        if self._quota == "fair":
            return max(int(self.shared.fair_share(self)), 1)
        return int(self._quota)

    def _page_cap(self) -> int:
        return min(self.quota, self.shared.num_pages)

    def resize_quota(self, quota: Union[int, str, None]) -> int:
        """Runtime quota change (the autoscale rebalancer's lever).

        Shrinking below current usage drains the overage through the
        engine's normal preemption path -- preempted requests release
        their pages to the shared pool and re-queue (at-least-once), so
        pages are never stranded on an over-quota view.  When the view
        aliases a shared KV array store the drained pages are *physical*:
        they become grantable to co-tenants in the same tick, and this
        view's remap forgets them (reading one raises).  Returns the
        number of requests preempted by the shrink."""
        self._quota = quota
        preempted = 0
        while self.used > self.quota or self.used_local > self.quota:
            if self.engine is None or not self.engine.preempt_newest():
                break          # no running request left to give back
            preempted += 1
        return preempted

    def admissible(self, req) -> bool:
        ok = super().admissible(req)
        if not ok:
            self._note_denial()
        return ok

    # -- view-local id space -------------------------------------------------
    def _new_ids(self, n: int, local: bool = False) -> List[int]:
        """n fresh view-local ids (recycled before the counter grows, so
        the id space stays as small as the view's peak usage)."""
        free = self._free_ids_local if local else self._free_ids
        ids = []
        for _ in range(n):
            if free:
                ids.append(free.pop())
            elif local:
                ids.append(self._next_id_local)
                self._next_id_local += 1
            else:
                ids.append(self._next_id)
                self._next_id += 1
        return ids

    def to_physical(self, ids: Sequence[int]) -> List[int]:
        """Physical page ids backing the view-local ``ids``.  Raises on
        any id this view does not currently own -- after preemption,
        quota shrink, or parking the physical page may already belong to
        a co-tenant, and reading it would leak another app's KV."""
        try:
            return [self._remap[v] for v in ids]
        except KeyError as e:
            raise KeyError(
                f"view {self.app!r} does not own page id {e.args[0]}: the "
                "physical page was reclaimed (isolation guard)") from None

    def to_physical_local(self, ids: Sequence[int]) -> List[int]:
        try:
            return [self._remap_local[v] for v in ids]
        except KeyError as e:
            raise KeyError(
                f"view {self.app!r} does not own ring page id {e.args[0]}: "
                "the physical page was reclaimed (isolation guard)") from None

    # -- physical KV array aliasing ------------------------------------------
    def bind_kv_store(self, store) -> None:
        """Alias this view onto the pod's shared device arrays for its KV
        shape (a :class:`~repro.serving.model_runner.KVArrayStore` from
        ``SharedPagePool.kv_store``).  Ring (local-group) pages then come
        from the store's shared local free list instead of a per-view
        space, since the local-layer arrays are shared too.  Must happen
        before any page is granted: the local id spaces differ."""
        if self.used or self.used_local:
            raise RuntimeError(
                f"view {self.app!r}: bind_kv_store with pages outstanding")
        self.kv_store = store
        store.users.add(self.app)

    def _local_free(self) -> Optional[List[int]]:
        """The physical free list ring pages draw from: the aliased
        store's shared one, else this view's private space."""
        if self.kv_store is not None and self.kv_store.free_local is not None:
            return self.kv_store.free_local
        return self.free_local

    # -- physical allocation via the shared pool ----------------------------
    def _alloc(self, n: int) -> Optional[List[int]]:
        if self.used + n > self.quota:
            self._denial_cause = "quota"
            self._note_denial()
            return None
        got = self.shared._take(n)
        if got is None:
            self._denial_cause = "physical"
            self._note_denial()
            return None
        self.used += n
        ids = self._new_ids(n)
        for vid, pid in zip(ids, got):
            self._remap[vid] = pid
        s = zensan.SAN
        if s is not None:
            s.grant(self, ids, got)
        t = obs_trace.TRACER
        if t is not None:
            t.instant("pool", "grant", self.app,
                      {"pages": n, "used": self.used})
        return ids

    def _dealloc(self, pages: List[int]) -> None:
        self.used -= len(pages)
        phys = [self._remap.pop(v) for v in pages]
        self._free_ids.extend(pages)
        s = zensan.SAN
        if s is not None:
            s.release(self, pages, phys)
        self.shared._give(phys)

    def cache_donate(self, pages: Sequence[int]) -> List[int]:
        """Donate freshly prefilled prompt pages to the prefix cache:
        uncharge this view's quota and forget the remap (the request
        will reference the pages by PHYSICAL id via ``shared_pages``),
        but do NOT return them to the shared free list -- the cache owns
        them now, and pod-level used_pages keeps reporting them."""
        self.used -= len(pages)
        phys = [self._remap.pop(v) for v in pages]
        self._free_ids.extend(pages)
        s = zensan.SAN
        if s is not None:
            s.cache_donated(self, phys, self.prefix_cache)
        t = obs_trace.TRACER
        if t is not None:
            t.instant("pool", "cache_donate", self.app,
                      {"pages": len(pages)})
        return phys

    def _alloc_local(self, n: int) -> Optional[List[int]]:
        """Ring pages index the local-attention layers' arrays -- the
        aliased store's shared ones, else the app's private set -- and
        still count against this view's quota: the quota caps each layer
        group's table independently."""
        src = self._local_free()
        if src is None:
            return None
        if self.used_local + n > self.quota:
            self._denial_cause = "quota"
            self._note_denial()
            return None
        if n > len(src):
            self._denial_cause = "physical"
            self._note_denial()
            return None
        self.used_local += n
        got = [src.pop() for _ in range(n)]
        ids = self._new_ids(n, local=True)
        for vid, pid in zip(ids, got):
            self._remap_local[vid] = pid
        s = zensan.SAN
        if s is not None:
            s.grant_local(self, got)
        return ids

    def _dealloc_local(self, pages: List[int]) -> None:
        if pages:
            self.used_local -= len(pages)
            phys = [self._remap_local.pop(v) for v in pages]
            self._free_ids_local.extend(pages)
            s = zensan.SAN
            if s is not None:
                s.release_local(self, phys)
            self._local_free().extend(phys)

    def _note_denial(self) -> None:
        d = self.shared.stats["denials"]
        d[self.app] = d.get(self.app, 0) + 1
        t = obs_trace.TRACER
        if t is not None:
            t.instant("pool", "denial", self.app,
                      {"cause": self._denial_cause})

    # -- engine hooks --------------------------------------------------------
    def attach(self, engine) -> None:
        self.engine = engine

    def preempt_any(self) -> bool:
        """Engine pressure hook.  A *physical* shortage is arbitrated
        across ALL of the pod's apps (fair-share victim selection); a
        *quota* denial can never be lifted by freeing co-tenants' pages,
        so the app sheds its own load instead of punishing neighbours."""
        if self._denial_cause == "quota":
            return self.engine is not None and self.engine.preempt_newest()
        return self.shared.preempt_for(self)

    def close(self) -> None:
        """Detach this app from the pod pool (on application release).
        The last aliasing tenant of a KV array store takes the store --
        and its device HBM -- with it."""
        s = zensan.SAN
        if s is not None:
            s.view_closed(self)
        self.engine = None
        if self.kv_store is not None:
            st = self.kv_store
            st.users.discard(self.app)
            if not st.users:
                # cached prefix pages live inside the store's arrays --
                # flush them back to the shared free list before the
                # arrays (and their content) go away
                self.shared.flush_prefix_caches(st.key)
                self.shared.kv_stores.pop(st.key, None)
            elif all(getattr(self.shared.views.get(u), "parked", False)
                     for u in st.users):
                # every remaining tenant is parked (KV on host): the
                # store stays registered for their unpark to revive, but
                # its device HBM must not sit idle meanwhile
                self.shared.flush_prefix_caches(st.key)
                st.drop_arrays()
            self.kv_store = None
        self.shared.views.pop(self.app, None)

    # -- accounting ---------------------------------------------------------
    @property
    def num_pages(self) -> int:          # engine/pretty-print compatibility
        return self.quota

    @num_pages.setter
    def num_pages(self, v: int) -> None:
        pass                             # base __init__ assigns; quota rules

    @property
    def physical_pages(self) -> int:
        return self.shared.num_pages

    @property
    def utilization(self) -> float:
        if self.groups is None:
            return self.used / max(self.quota, 1)
        return ((self.groups.w_global * self.used
                 + self.groups.w_local * self.used_local)
                / max(self.quota, 1))
