"""Global prefix cache: refcounted copy-on-write KV pages on the paged
data plane.

At high-overlap serving load most prompts share a long common prefix (a
system prompt, a few-shot template), so the dominant prefill cost is
recomputing KV every co-tenant has already computed.  This module is the
index that removes that waste: a radix/trie keyed on token-id prefixes at
**page granularity** whose nodes own **refcounted, read-only physical
pages** in the pod's shared pool.  Prefill then computes only the
un-cached suffix (see ``PagedRunner``'s chunked prefill) and appends the
suffix KV into freshly granted private pages.

Node classes:

* **full** nodes hold exactly ``PAGE_SIZE`` tokens and may have children
  -- the radix edges.  A request whose prompt matches a chain of full
  nodes references those *physical* pages directly in its decode page
  table (``Request.shared_pages``), never writing them.
* **partial** leaves hold the tail of some earlier prompt (< PAGE_SIZE
  tokens).  A later prompt that agrees with the leaf on a non-empty lead
  and then diverges -- or extends past it -- triggers **copy-on-write**:
  the page is copied into the requester's private grant (the matched
  ``lead`` slots) and the divergent suffix is written there.  Divergence
  exactly at a page boundary is a plain miss, no copy.

Lifecycle (see docs/runtime.md):
``pin`` (lookup; refs++ along the matched chain) -> suffix prefill ->
``insert`` (donate the prompt's full pages; created nodes are pinned for
the donor) -> ``unpin`` on release/park -> refcount-0 LRU eviction under
pool pressure (``SharedPagePool._take`` shortfall).  Pinned nodes are
never evicted -- a mid-decode request's prefix pages cannot be pulled
out from under it.

Ownership: cached pages belong to the CACHE, not to any request or
``PoolView`` -- they are excluded from per-view quota charging (the view
"donates" them via ``cache_donate``) but stay out of the pool's free
list, so pod-level ``used_pages``/utilization still reports them.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

from repro.analysis import zensan
from repro.obs import trace as obs_trace
from repro.serving.kv_cache import PAGE_SIZE


def _lcp(a: Sequence[int], b: Sequence[int]) -> int:
    """Longest-common-prefix length of two token sequences."""
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class PrefixNode:
    """One cached page: ``tokens`` (the page's token ids), the physical
    ``page`` holding their KV, a refcount (pins by in-flight requests),
    and an LRU stamp.  Full nodes are radix edges; partial nodes are
    leaves (COW sources)."""

    __slots__ = ("tokens", "page", "full", "children", "partials",
                 "parent", "refs", "last_used")

    def __init__(self, tokens: Tuple[int, ...], page: int, full: bool,
                 parent: Optional["PrefixNode"]):
        self.tokens = tokens
        self.page = page
        self.full = full
        self.children = {}           # full-page token tuple -> PrefixNode
        self.partials: List["PrefixNode"] = []
        self.parent = parent
        self.refs = 0
        self.last_used = 0


@dataclass
class PrefixMatch:
    """One pinned lookup result.  ``phys_pages`` are the fully-matched
    chain's PHYSICAL page ids, table-ready (requests store them on
    ``shared_pages``, never translated through a view remap).
    ``cow_src`` is the physical page a partial/diverged match must be
    copied from before the requester writes past ``cached_len``."""

    phys_pages: List[int] = field(default_factory=list)
    cached_len: int = 0
    cow_src: Optional[int] = None
    nodes: List[PrefixNode] = field(default_factory=list)

    @property
    def hit(self) -> bool:
        return self.cached_len > 0


class PrefixCache:
    """Radix index over page-granular token prefixes -> refcounted
    read-only physical pages.

    ``free_fn`` returns evicted pages to whatever free list granted them
    (``SharedPagePool._give`` for pod-shared tenancy, the private pool's
    free list otherwise).  One cache is keyed per (KV shape, model,
    seed): KV content is a function of tokens AND params, so tenants may
    share a cache only when they share both the device arrays and the
    weights."""

    def __init__(self, key: Tuple, free_fn: Callable[[List[int]], None]):
        self.key = key
        self.free_fn = free_fn
        self.root = PrefixNode((), -1, True, None)
        self.nodes: List[PrefixNode] = []
        self._clock = 0
        self.users: set = set()      # app names bound to this cache
        self.stats = {"lookups": 0, "hits": 0, "hit_pages": 0,
                      "hit_tokens": 0, "inserted_pages": 0,
                      "evicted_pages": 0, "cow_copies": 0, "unpinned": 0}

    # -- accounting ----------------------------------------------------------
    @property
    def num_pages(self) -> int:
        """Physical pages the cache currently owns."""
        return len(self.nodes)

    @property
    def hit_rate(self) -> float:
        return self.stats["hits"] / max(self.stats["lookups"], 1)

    def _touch(self, node: PrefixNode) -> None:
        self._clock += 1
        node.last_used = self._clock

    # -- lookup / pin --------------------------------------------------------
    def pin(self, tokens: Sequence[int], *, max_len: Optional[int] = None,
            max_full: Optional[int] = None) -> PrefixMatch:
        """Match ``tokens`` against the trie and PIN the matched chain
        (refs++ on every node, so eviction cannot take the pages while
        the requester decodes through them).  The receipt is the match:
        callers must keep it and later ``unpin(match.nodes)``.

        ``max_len`` caps the usable cached length (prefill passes
        ``prompt_len - 1``: at least one position must be computed to
        produce the first-token logits).  ``max_full`` restricts the
        match to full-page nodes only (parking's re-attach path, which
        must reproduce an exact earlier page-chain boundary)."""
        toks = tuple(tokens)
        if max_len is not None:
            toks = toks[:max_len]
        self.stats["lookups"] += 1
        chain: List[PrefixNode] = []
        node = self.root
        i = 0
        while ((i + 1) * PAGE_SIZE <= len(toks)
               and (max_full is None or i < max_full)):
            child = node.children.get(toks[i * PAGE_SIZE:(i + 1) * PAGE_SIZE])
            if child is None:
                break
            chain.append(child)
            node = child
            i += 1
        full_pages = [n.page for n in chain]
        cached_len = i * PAGE_SIZE
        cow_src = None
        if max_full is None:
            rem = toks[i * PAGE_SIZE:]
            if rem:
                best, best_l = None, 0
                for cand in list(node.children.values()) + node.partials:
                    l = _lcp(cand.tokens, rem)
                    if l > best_l:
                        best, best_l = cand, l
                if best is not None:
                    # divergence (or extension) INSIDE a page: the lead
                    # slots are reusable via copy-on-write; divergence
                    # exactly at the page boundary lands here with
                    # best_l == 0 and stays a plain miss
                    chain.append(best)
                    cow_src = best.page
                    cached_len += best_l
        for n in chain:
            n.refs += 1
            self._touch(n)
        s = zensan.SAN
        if s is not None:
            s.pinned(self, chain)
        if cached_len > 0:
            self.stats["hits"] += 1
            self.stats["hit_pages"] += len(full_pages)
            self.stats["hit_tokens"] += cached_len
        t = obs_trace.TRACER
        if t is not None:
            # the cache's own view of the lookup (the request-scoped
            # prefix_pin instant is the attach-side receipt)
            t.instant("pool", "prefix_lookup", None,
                      {"hit": cached_len > 0, "cached_len": cached_len,
                       "full_pages": len(full_pages),
                       "cow": cow_src is not None})
        return PrefixMatch(phys_pages=full_pages, cached_len=cached_len,
                           cow_src=cow_src, nodes=chain)

    def unpin(self, nodes: Sequence[PrefixNode]) -> int:
        """Drop one pin from each node; returns how many nodes became
        evictable (refs hit 0) -- the receipt callers fold into their
        accounting (ZL005)."""
        released = 0
        for n in nodes:
            n.refs -= 1
            assert n.refs >= 0, "prefix-cache pin/unpin imbalance"
            self._touch(n)
            if n.refs == 0:
                released += 1
        s = zensan.SAN
        if s is not None:
            s.unpinned(self, nodes)
        self.stats["unpinned"] += released
        return released

    # -- insert --------------------------------------------------------------
    def probe_new(self, tokens: Sequence[int],
                  from_page: int) -> Tuple[int, bool]:
        """How much of ``tokens`` insert() would ADOPT, starting at full
        page ``from_page`` (the depth the donor matched at pin time):
        ``(n_new_full_pages, partial_is_new)``.  Returns (0, False) when
        a racing tenant already cached past ``from_page`` -- donated
        pages must extend the donor's own shared prefix contiguously, so
        a raced insert adopts nothing and the donor simply keeps its
        private copies."""
        toks = tuple(tokens)
        n_full = len(toks) // PAGE_SIZE
        node = self.root
        depth = 0
        while depth < n_full:
            child = node.children.get(
                toks[depth * PAGE_SIZE:(depth + 1) * PAGE_SIZE])
            if child is None:
                break
            node = child
            depth += 1
        if depth != from_page:
            return 0, False
        rem = toks[n_full * PAGE_SIZE:]
        partial_new = bool(rem) and not any(
            _lcp(c.tokens, rem) == len(rem)
            for c in list(node.children.values()) + node.partials)
        return n_full - depth, partial_new

    def insert(self, tokens: Sequence[int], from_page: int,
               phys_pages: Sequence[int],
               partial_page: Optional[int] = None) -> List[PrefixNode]:
        """Adopt donated pages into the trie: one full node per entry of
        ``phys_pages`` (full pages ``from_page``..), plus one partial
        leaf for the prompt tail when ``partial_page`` is given.  The
        caller sized the donation with :meth:`probe_new` in the same
        engine tick, so creation cannot race past it.  Created nodes
        come back PINNED for the donor (it still decodes through those
        pages); the partial leaf is pinned too and released with the
        rest at ``unpin`` time."""
        toks = tuple(tokens)
        node = self.root
        for j in range(from_page):
            node = node.children[toks[j * PAGE_SIZE:(j + 1) * PAGE_SIZE]]
        created: List[PrefixNode] = []
        for off, page in enumerate(phys_pages):
            j = from_page + off
            key = toks[j * PAGE_SIZE:(j + 1) * PAGE_SIZE]
            assert len(key) == PAGE_SIZE and key not in node.children, \
                "insert() past probe_new(): donation raced"
            child = PrefixNode(key, int(page), True, node)
            node.children[key] = child
            self.nodes.append(child)
            created.append(child)
            node = child
        if partial_page is not None:
            rem = toks[(from_page + len(phys_pages)) * PAGE_SIZE:]
            assert 0 < len(rem) < PAGE_SIZE, "partial insert needs a tail"
            leaf = PrefixNode(rem, int(partial_page), False, node)
            node.partials.append(leaf)
            self.nodes.append(leaf)
            created.append(leaf)
        for n in created:
            n.refs += 1
            self._touch(n)
        s = zensan.SAN
        if s is not None:
            s.inserted(self, created)
        self.stats["inserted_pages"] += len(created)
        return created

    # -- eviction (refcount-0 LRU under pool pressure) -----------------------
    def peek_evictable(self) -> Optional[PrefixNode]:
        """The least-recently-used node with no pins and no dependants
        (leaf-first: evicting an interior node would orphan its
        subtree), or None.  Pinned nodes are NEVER candidates."""
        best = None
        for n in self.nodes:
            if n.refs or n.children or n.partials:
                continue
            if best is None or n.last_used < best.last_used:
                best = n
        return best

    def evict(self, node: PrefixNode) -> List[int]:
        """Remove one evictable node, returning its page to the pool via
        ``free_fn``.  Returns the freed physical page ids."""
        assert node.refs == 0 and not node.children and not node.partials
        parent = node.parent
        if node.full:
            parent.children.pop(node.tokens, None)
        else:
            parent.partials.remove(node)
        self.nodes.remove(node)
        s = zensan.SAN
        if s is not None:
            s.evicted(self, node)
        freed = [node.page]
        self.free_fn(freed)
        self.stats["evicted_pages"] += len(freed)
        return freed

    def evict_lru(self, need: int) -> int:
        """Evict refcount-0 nodes LRU-first until ``need`` pages are
        freed or no candidate remains; returns pages actually freed."""
        freed = 0
        while freed < need:
            victim = self.peek_evictable()
            if victim is None:
                break
            freed += len(self.evict(victim))
        return freed

    def flush(self) -> int:
        """Evict every unpinned node (KV-store teardown: the device
        arrays holding the cached content are going away)."""
        return self.evict_lru(len(self.nodes))
