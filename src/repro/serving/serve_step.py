"""Serving steps: prefill and decode, shaped by the materialization plan."""

from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.core.materializer import Plan
from repro.models.model import Model
from repro.models.transformer import ImplConfig


def impl_from_plan(plan: Plan, unroll_blocks: bool = False,
                   num_blocks_override: Optional[int] = None) -> ImplConfig:
    return ImplConfig(attn_impl=plan.attn_impl, remat="none",
                      scan_blocks=not unroll_blocks,
                      unroll_blocks=unroll_blocks,
                      num_blocks_override=num_blocks_override)


def make_prefill_step(model: Model, cache_len: int) -> Callable:
    def prefill(params, batch):
        logits, cache = model.prefill(params, batch, cache_len)
        return logits, cache
    return prefill


def make_decode_step(model: Model, sample: bool = False,
                     temperature: float = 1.0) -> Callable:
    """decode(params, tokens (B,1), cache, pos) -> (next (B,1), logits, cache)."""
    def decode(params, tokens, cache, pos):
        logits, cache = model.decode_step(params, tokens, cache, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return decode
