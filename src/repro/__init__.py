"""Zenix: resource-centric adaptive execution for bulky training/serving
jobs on TPU pods (JAX).  Reproduction of "BulkX / Zenix: Efficient Execution
of Bulky Serverless Applications" adapted to the TPU/JAX substrate."""

__version__ = "0.1.0"
