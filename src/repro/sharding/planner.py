"""Sharding planner: Plan + logical axes -> concrete NamedShardings.

The materializer decides *placement strategy* (which components are local
vs. sharded); this module translates that into per-leaf PartitionSpecs,
guarding divisibility (a dim that doesn't divide its mesh axes falls back
to replication -- e.g. GQA KV heads of 8 on a 16-way model axis).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.materializer import Plan
from repro.models import layers as L

FSDP_MIN_ELEMS = 1 << 16


def _axes_size(mesh_spec, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh_spec.axis_size(a)
    return n


def logical_rules(plan: Plan, cfg: ModelConfig) -> Dict[str, Tuple[str, ...]]:
    """logical axis name -> mesh axes tuple (before divisibility checks)."""
    tp: Tuple[str, ...] = ("model",) if plan.tp else ()
    rules: Dict[str, Tuple[str, ...]] = {
        "vocab": tp,
        "embed": (),
        "embed2": tp,
        "q_heads": tp,
        "kv_heads": tp,
        "head_dim": (),
        "ffn": tp,
        "experts": ("model",) if plan.ep else (),
        "expert_ffn": () if plan.ep else tp,
        "ssm_inner": tp,
        "ssm_heads": tp,
        "ssm_state": (),
        "ssm_conv": (),
        "conv_w": (),
        "blocks": (),
        "lora": (),
        None: (),
    }
    return rules


def spec_for_leaf(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                  rules: Dict, plan: Plan,
                  extra_axes: Tuple[str, ...] = ()) -> P:
    """PartitionSpec for one parameter leaf (divisibility-guarded).

    ``extra_axes``: mesh axes over which to additionally shard the largest
    still-unsharded dim (FSDP over 'data'; ZeRO over the full DP group)."""
    entries = []
    used = set()
    for dim, ax in enumerate(axes):
        mesh_axes = rules.get(ax, ())
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        if mesh_axes and shape[dim] % _axes_size(plan.mesh, mesh_axes) == 0:
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
            used.update(mesh_axes)
        else:
            entries.append(None)
    extra = tuple(a for a in extra_axes if a not in used)
    if extra and int(np.prod(shape)) >= FSDP_MIN_ELEMS:
        sz = _axes_size(plan.mesh, extra)
        # Preference order (measured consequence: sharding the contraction
        # ('embed') dim makes the partitioner psum ACTIVATIONS per matmul --
        # 571 all-reduces x ~2.9 GB on command-r train -- instead of
        # gathering the much smaller weights):
        #   1. extend an already model-sharded (non-contracting) dim;
        #   2. largest unsharded non-'embed' dim;
        #   3. largest unsharded dim (embed as last resort).
        if getattr(plan, "fsdp_contracting", False):
            # legacy layout family: largest unsharded dim, embed included
            cands = [(shape[d], d) for d in range(len(shape))
                     if entries[d] is None and shape[d] % sz == 0
                     and axes[d] != "blocks"]
            if cands:
                _, d = max(cands)
                entries[d] = extra if len(extra) > 1 else extra[0]
            return P(*entries)
        ext = None
        for d in range(len(shape)):
            cur = entries[d]
            if cur is None or axes[d] == "blocks":
                continue
            cur_t = cur if isinstance(cur, tuple) else (cur,)
            if shape[d] % (_axes_size(plan.mesh, cur_t) * sz) == 0:
                ext = (d, cur_t + extra)
                break
        if ext is not None:
            d, spec = ext
            entries[d] = spec
        else:
            cands = [(shape[d], d) for d in range(len(shape))
                     if entries[d] is None and shape[d] % sz == 0
                     and axes[d] != "blocks"]
            non_embed = [(n, d) for n, d in cands if axes[d] != "embed"]
            pool = non_embed or cands
            if pool:
                _, d = max(pool)
                entries[d] = extra if len(extra) > 1 else extra[0]
    return P(*entries)


def _zero_axes(plan: Plan) -> Tuple[str, ...]:
    """ZeRO shards optimizer state over the full data-parallel group."""
    return plan.batch_axes or ("data",)


def param_specs_tree(plan: Plan, cfg: ModelConfig, specs) -> Any:
    """Spec (L.Spec) tree -> PartitionSpec tree."""
    rules = logical_rules(plan, cfg)
    extra = ("data",) if plan.fsdp else ()

    def leaf(s: L.Spec) -> P:
        return spec_for_leaf(s.axes, s.shape, rules, plan, extra)

    return jax.tree.map(leaf, specs, is_leaf=L.is_spec)


def opt_state_specs_tree(plan: Plan, cfg: ModelConfig, specs) -> Any:
    """Optimizer-state sharding: params rules + ZeRO over the DP group."""
    rules = logical_rules(plan, cfg)
    extra: Tuple[str, ...] = ()
    if plan.fsdp:
        extra = ("data",)
    elif plan.zero:
        extra = _zero_axes(plan)

    def leaf(s: L.Spec) -> P:
        return spec_for_leaf(s.axes, s.shape, rules, plan, extra)

    return jax.tree.map(leaf, specs, is_leaf=L.is_spec)


def batch_spec(plan: Plan, extra_dims: int = 1) -> P:
    """Sharding for (B, S, ...) batch arrays."""
    b = plan.batch_axes if plan.batch_axes else None
    if len(plan.batch_axes) == 1:
        b = plan.batch_axes[0]
    return P(b, *([None] * extra_dims))


def activation_spec(plan: Plan) -> P:
    """(B, S, D) activation constraint."""
    b = plan.batch_axes or None
    if b and len(b) == 1:
        b = b[0]
    return P(b, None, None)


def cache_specs_tree(plan: Plan, cfg: ModelConfig, cache_structs) -> Any:
    """KV-cache / recurrent-state sharding specs (stacked: leading NB dim).

    Leaf kinds are identified by their pytree key (robust against shape
    coincidences):
      k / v / cross_k / cross_v : (NB, B, KV, S, hd) -> batch, heads|seq
      wkv                       : (NB, B, H, hd, hd) -> batch, heads?
      ssm                       : (NB, B, H, P, N)   -> batch, heads?
      conv / shift_t / shift_c  : batch only
    """
    mesh_spec = plan.mesh
    tp_size = mesh_spec.axis_size("model")
    batch = plan.batch_axes or None
    if batch and len(batch) == 1:
        batch = batch[0]

    def bspec_for(shp) -> Any:
        if batch is None:
            return None
        if shp[1] % _axes_size(mesh_spec, plan.batch_axes) != 0:
            return None
        return batch

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_structs)
    out = []
    for path, s in flat:
        key = str(getattr(path[-1], "key", ""))
        shp = s.shape
        bspec = bspec_for(shp)
        if key in ("k", "v", "cross_k", "cross_v"):
            # (NB, B, KV, S, hd)
            kv_spec = None
            seq_spec = None
            if plan.kv_shard_heads and shp[2] % tp_size == 0:
                kv_spec = "model"
            elif plan.kv_shard_seq or plan.seq_axes:
                cand = plan.seq_axes or ("model",)
                if shp[3] % _axes_size(mesh_spec, cand) == 0:
                    seq_spec = cand if len(cand) > 1 else cand[0]
            out.append(P(None, bspec, kv_spec, seq_spec, None))
        elif key in ("wkv", "ssm"):
            # (NB, B, H, x, y): shard heads over model when divisible
            hspec = "model" if (plan.tp and shp[2] % tp_size == 0) else None
            out.append(P(None, bspec, hspec, None, None))
        else:
            out.append(P(*([None, bspec] + [None] * (len(shp) - 2))))
    return jax.tree.unflatten(treedef, out)


def to_named(tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, tree,
        is_leaf=lambda x: isinstance(x, P))


def ep_dispatch_spec(plan: Plan) -> Optional[P]:
    """(E, C, D) dispatch-buffer constraint for MoE expert parallelism."""
    if not plan.ep:
        return None
    cdim = plan.batch_axes or None
    if cdim and len(cdim) == 1:
        cdim = cdim[0]
    return P("model", cdim, None)
