"""Version-compatibility shims for the range of jax releases we support.

The repo targets the public ``jax.shard_map`` / ``jax.sharding.AxisType``
surface of recent jax; older releases (<= 0.4.x, the pinned toolchain on
this image) expose ``shard_map`` under ``jax.experimental`` with a
``check_rep`` keyword instead of ``check_vma``.  All model code routes
through this module so the difference lives in exactly one place.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:  # pragma: no cover - exercised on the pinned 0.4.x toolchain
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
