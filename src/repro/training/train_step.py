"""Train step factory: model + plan -> jit-able step with shardings.

Implements the plan's execution strategy: microbatch gradient accumulation
(lax.scan), remat policy (inside the model's block scan), optional int8
gradient compression on the cross-pod axis, and the AdamW update.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.core.materializer import Plan
from repro.models.model import Model
from repro.models.transformer import ImplConfig
from repro.training import optimizer as opt


def impl_from_plan(plan: Plan, unroll_blocks: bool = False,
                   num_blocks_override: Optional[int] = None) -> ImplConfig:
    return ImplConfig(
        attn_impl=plan.attn_impl,
        remat=plan.remat,
        scan_blocks=not unroll_blocks,
        unroll_blocks=unroll_blocks,
        num_blocks_override=num_blocks_override,
    )


def _compress_int8(g: jax.Array) -> jax.Array:
    """int8 quantize-dequantize (simulated compressed all-reduce payload).

    On a real multi-pod fabric this halves/quarters the cross-pod gradient
    bytes; under jit we model it as fake-quant so XLA sees the narrower
    payload on the pod-axis reduction when combined with reduce-scatter
    scheduling (beyond-paper optimization, §Perf)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(g.dtype) * scale


def make_train_step(model: Model, plan: Plan,
                    opt_cfg: Optional[opt.OptimizerConfig] = None,
                    shape: Optional[ShapeConfig] = None) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch`` leaves have global shapes (B, S, ...); with plan.microbatch>1
    the step scans over microbatch slices accumulating fp32 grads.
    """
    opt_cfg = opt_cfg or opt.OptimizerConfig()
    mb = max(plan.microbatch, 1)

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if mb == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # SPMD hazard (measured: the whole batch replicated per device,
            # 47 GB temp on gemma3 train; collective-permute storms on
            # zamba2): dynamic_slice with a traced start on the
            # batch-SHARDED dim makes the partitioner gather it.  Instead
            # split the batch dim statically as (per_mb, mb, ...) -- the
            # contiguous outer blocks line up with the data shards, so the
            # reshape keeps dim0 sharded -- and scan over the unsharded mb
            # dim.  Microbatch grouping is irrelevant to summed gradients.
            def split_mb(x):
                per_mb = x.shape[0] // mb
                xr = x.reshape(per_mb, mb, *x.shape[1:])
                return jnp.swapaxes(xr, 0, 1)        # (mb, per_mb, ...)

            batch_mb = jax.tree.map(split_mb, batch)

            def acc_body(carry, mbatch):
                gacc, lacc = carry
                (l, m), g = grad_fn(params, mbatch)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                acc_body, (gz, jnp.zeros((), jnp.float32)), batch_mb)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = lsum / mb
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        if plan.grad_compression == "int8":
            grads = jax.tree.map(_compress_int8, grads)

        new_params, new_opt, om = opt.adamw_update(grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return new_params, new_opt, metrics

    return step
