"""AdamW with fp32 master weights, global-norm clipping and schedules.

Pure-JAX (no optax): the optimizer state layout must be visible to the
sharding planner (ZeRO shards m/v/master over the data axis) and to the
checkpointer, so we keep it a plain pytree:

    opt_state = {"m": fp32, "v": fp32, "master": fp32, "count": i32}
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Params) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads: Params, opt_state: Dict[str, Any], cfg: OptimizerConfig
                 ) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new bf16 params, new opt state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        master = master - lr * step
        return m, v, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, w)
           for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_w = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), new_w)
    new_state = {"m": new_m, "v": new_v, "master": new_w, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_structs(param_structs: Params) -> Dict[str, Any]:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, param_structs),
        "v": jax.tree.map(f32, param_structs),
        "master": jax.tree.map(f32, param_structs),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
