"""Data pipeline: deterministic synthetic token streams with background
prefetch and per-host sharding.

Synthetic data is generated from a seeded Markov-ish process so training
loss *decreases* measurably (structure to learn) while remaining fully
offline/deterministic.  The loader prefetches on a background thread
(double buffering -- the paper's proactive environment setup analog on the
input path) and slices per-host shards for multi-host launches."""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: int = 64        # size of the latent transition table
    host_count: int = 1
    host_index: int = 0


class SyntheticLM:
    """Deterministic structured token stream: x_{t+1} = f(x_t) + noise."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.table = rng.integers(0, cfg.vocab_size,
                                  size=(cfg.structure,), dtype=np.int64)
        self._step = 0

    def _batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 1 + step)
        b = cfg.global_batch // cfg.host_count
        start = rng.integers(0, cfg.structure, size=(b, 1))
        t = np.arange(cfg.seq_len + 1)[None, :]
        latent = (start + t) % cfg.structure
        toks = self.table[latent]
        noise = rng.random((b, cfg.seq_len + 1)) < 0.05
        rand = rng.integers(0, cfg.vocab_size, size=(b, cfg.seq_len + 1))
        toks = np.where(noise, rand, toks)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = self._step
        while True:
            yield self._batch(step)
            step += 1

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Random access by step index: exact replay after restart (the
        recovery path re-reads the same batches from the last cut)."""
        return self._batch(step)


class PrefetchLoader:
    """Background-thread prefetch with bounded depth (double buffering)."""

    def __init__(self, source: Iterator[Dict[str, np.ndarray]],
                 depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._src = source
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        for item in self._src:
            if self._stop.is_set():
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass


def make_loader(cfg: DataConfig, start_step: int = 0,
                prefetch: int = 2) -> PrefetchLoader:
    src = SyntheticLM(cfg)
    src._step = start_step
    return PrefetchLoader(iter(src), depth=prefetch)
