"""repro.runtime -- the resource-centric public API.

One surface for train, serve, and simulate::

    from repro.runtime import Application, Cluster, JaxExecutor

    cluster = Cluster(pods=1, history=history, executor=JaxExecutor())
    handle = cluster.submit(Application.train("tinyllama-1.1b",
                                              reduced=True))
    handle.run(steps=20)
    handle.release()

See docs/runtime.md for the full lifecycle.
"""

from repro.runtime.application import REDUCED_SHAPES, Application
from repro.runtime.cluster import AppHandle, Cluster
from repro.runtime.executors import Executor, JaxExecutor, NullExecutor
from repro.runtime.options import ScalePolicy, ServeOptions
from repro.runtime.simulate import measure_cluster_throughput, replay_trace

__all__ = [
    "Application", "AppHandle", "Cluster",
    "Executor", "JaxExecutor", "NullExecutor",
    "REDUCED_SHAPES", "ScalePolicy", "ServeOptions",
    "measure_cluster_throughput", "replay_trace",
]

# the autoscale control plane lives in repro.autoscale (imported lazily
# by Cluster.enable_autoscale / AppHandle.park to keep simulation-only
# paths import-light)
