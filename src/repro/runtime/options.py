"""Typed serve-time options: the resource-centric serve API surface.

``ServeOptions`` collapses the serving kwargs that used to sprawl
across ``Application.serve(**options)``, the executors' ``opts.get``
calls, and ``launch/serve.py`` flags into one frozen, validated
dataclass.  Cross-field rules that were previously enforced deep in
the stack (e.g. ``build_runner`` rejecting dense + prefix cache) are
checked here at construction time, where the error points at the line
that made the bad choice.

``ScalePolicy`` declares the *platform-owned* scaling dimensions for
one app -- replica count and continuous-batch width -- plus the
predictive-unpark knob.  The app states bounds and targets; the
autoscale control plane (``repro.autoscale``) moves within them.

Legacy keyword arguments still work for one release via
``ServeOptions.from_kwargs`` behind a ``DeprecationWarning`` raised in
``Application.serve``.
"""

from dataclasses import dataclass, fields
from typing import Any, Dict, Optional

BACKENDS = ("dense", "paged")
POOL_POLICIES = ("fixed", "history", "peak")


@dataclass(frozen=True)
class ScalePolicy:
    """Bounds and targets for platform-owned scaling of one serve app.

    Replica scaling target-tracks the *windowed* router queue depth per
    replica; batch scaling target-tracks decode occupancy.  Setting
    ``min_replicas=0`` allows scale-to-zero, which is exactly the PR 3
    park path (KV to host, pages and param bytes released).
    """

    min_replicas: int = 1
    max_replicas: int = 1
    #: windowed router+engine queue depth per replica that triggers
    #: adding a replica
    target_queue_per_replica: float = 4.0
    #: decode occupancy (running / (replicas * max_batch)) below which a
    #: replica is drained (and below which the batch is narrowed)
    shrink_occupancy: float = 0.25
    #: occupancy at or above which the batch is widened
    grow_occupancy: float = 0.9
    #: continuous-batch width bounds; ``batch_max=None`` disables batch
    #: scaling (the width stays at ``ServeOptions.max_batch``)
    batch_min: int = 1
    batch_max: Optional[int] = None
    #: wake a parked app ahead of the EWMA-forecast next arrival
    predictive_unpark: bool = True
    unpark_lead_s: float = 1.0

    def __post_init__(self):
        if self.min_replicas < 0:
            raise ValueError("ScalePolicy: min_replicas must be >= 0 "
                             f"(got {self.min_replicas})")
        if self.max_replicas < max(self.min_replicas, 1):
            raise ValueError(
                f"ScalePolicy: max_replicas={self.max_replicas} below "
                f"min_replicas={self.min_replicas} (and must be >= 1)")
        if self.batch_min < 1:
            raise ValueError("ScalePolicy: batch_min must be >= 1 "
                             f"(got {self.batch_min})")
        if self.batch_max is not None and self.batch_max < self.batch_min:
            raise ValueError(
                f"ScalePolicy: batch_max={self.batch_max} below "
                f"batch_min={self.batch_min}")
        if not (0.0 <= self.shrink_occupancy < self.grow_occupancy <= 1.0):
            raise ValueError(
                "ScalePolicy: need 0 <= shrink_occupancy < grow_occupancy "
                f"<= 1 (got {self.shrink_occupancy} / {self.grow_occupancy})")
        if self.unpark_lead_s < 0:
            raise ValueError("ScalePolicy: unpark_lead_s must be >= 0")

    @property
    def scales_replicas(self) -> bool:
        return self.max_replicas > 1 or self.min_replicas == 0

    @property
    def scales_batch(self) -> bool:
        return self.batch_max is not None


@dataclass(frozen=True)
class ServeOptions:
    """Everything a serve application asks of the data plane.

    ``max_batch=None`` and ``pool_pages=None`` defer to the executor's
    backend-specific defaults.  ``replicas`` is the *initial* replica
    count; with a ``scale`` policy attached the controller moves it
    within ``[min_replicas, max_replicas]``.
    """

    backend: str = "dense"
    max_batch: Optional[int] = None
    cache_len: int = 256
    replicas: int = 1
    #: pod-shared pool sizing / placement
    pool_pages: Optional[int] = None
    policy: str = "history"
    private_pool: bool = False
    quota_pages: Optional[int] = None
    weight: float = 1.0
    #: paged-backend features
    swa_rings: bool = True
    alias_kv: bool = True
    prefix_cache: bool = False
    chunk_pages: Optional[int] = None
    #: platform-owned scaling dimensions (None = fixed footprint)
    scale: Optional[ScalePolicy] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"ServeOptions: unknown backend "
                             f"{self.backend!r} (expected one of {BACKENDS})")
        if self.prefix_cache and self.backend != "paged":
            # moved here from build_runner: fail where the option is set
            raise ValueError(
                "ServeOptions: prefix_cache=True requires backend='paged' "
                "(the dense backend has no page identity to share)")
        if self.replicas < 1:
            raise ValueError("ServeOptions: replicas must be >= 1 "
                             f"(got {self.replicas})")
        if self.replicas > 1 and self.private_pool:
            raise ValueError(
                "ServeOptions: replicas > 1 requires the pod-shared pool "
                "(replicas alias one KV array set; private_pool=True "
                "would duplicate it)")
        if self.max_batch is not None and self.max_batch < 1:
            raise ValueError("ServeOptions: max_batch must be >= 1 "
                             f"(got {self.max_batch})")
        if self.policy not in POOL_POLICIES:
            raise ValueError(f"ServeOptions: unknown pool policy "
                             f"{self.policy!r} (expected {POOL_POLICIES})")
        if self.weight <= 0:
            raise ValueError("ServeOptions: weight must be > 0 "
                             f"(got {self.weight})")
        if self.scale is not None and self.scale.max_replicas < self.replicas:
            raise ValueError(
                f"ServeOptions: replicas={self.replicas} exceeds "
                f"scale.max_replicas={self.scale.max_replicas}")

    @classmethod
    def from_kwargs(cls, kwargs: Dict[str, Any]) -> "ServeOptions":
        """Build from the legacy ``Application.serve(**options)`` kwargs.

        Unknown keys are a ``TypeError`` (same contract as a real
        signature) so typos don't silently vanish into a dict.
        """
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(kwargs) - known)
        if unknown:
            raise TypeError(
                f"ServeOptions: unknown option(s) {unknown}; known "
                f"options: {sorted(known)}")
        return cls(**kwargs)

    def asdict(self) -> Dict[str, Any]:
        """Shallow field dict (``scale`` stays a ScalePolicy object) --
        the legacy ``Application.options`` mirror."""
        return {f.name: getattr(self, f.name) for f in fields(self)}
