"""Execution backends for the runtime: the simulator and real jax share
ONE submission path and differ only in the executor bound at submit time.

* :class:`NullExecutor` -- no jax, no device state.  Training steps are
  no-ops and serving engines run without step functions: exactly what the
  scheduler-scalability and placement benchmarks need (pure decision
  throughput, like the paper's §6.2 measurement).
* :class:`JaxExecutor` -- builds the model, compiles the step through the
  CompileCache, feeds synthetic data, writes async checkpoints, and runs
  real prefill/decode through the ServingEngine.

Executors keep all per-application state on ``handle.exec_state`` so one
executor instance can drive many applications on one cluster.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, TYPE_CHECKING

from repro.core.compile_cache import CompileCache, plan_layout_key
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagePool

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.runtime.cluster import AppHandle

DEFAULT_POOL_PAGES = 256


class Executor:
    """Interface the AppHandle lifecycle drives."""

    name = "null"
    default_pool_pages = DEFAULT_POOL_PAGES

    def bind(self, handle: "AppHandle") -> None:
        """Materialize executable state for a placed application."""
        if handle.app.kind == "serve":
            handle.exec_state["engine"] = self.build_engine(handle)

    def train_step(self, handle: "AppHandle") -> Dict[str, float]:
        return {"loss": 0.0}

    def build_pool(self, handle: "AppHandle") -> PagePool:
        """The application's KV page pool.

        Default: a quota/weight-scoped *view* onto the pod's single
        :class:`~repro.serving.tenancy.SharedPagePool`, so every serve app
        placed on one pod draws from one physical pool (the paper's
        resource sharing).  ``options['private_pool']=True`` opts out into
        the old one-pool-per-app peak provisioning (the benchmark's
        baseline arm).

        When the app serves through the paged backend on a mixed
        global/sliding-window stack, the pool carries the model's
        :class:`~repro.serving.kv_cache.PageGroups` so local-attention
        layers are charged a bounded ring instead of the growing table
        (``options['swa_rings']=False`` opts out, the benchmark's no-ring
        arm)."""
        opts = handle.app.options
        pages = int(opts.get("pool_pages", self.default_pool_pages))
        policy = opts.get("policy", "history")
        groups = None
        if (opts.get("backend") == "paged" and handle.app.config is not None
                and opts.get("swa_rings", True)):
            from repro.serving.kv_cache import PageGroups
            g = PageGroups.from_config(handle.app.config)
            groups = g if g.local_layers else None
        if opts.get("private_pool"):
            return PagePool(pages, history=handle.cluster.history,
                            app=handle.app.name, policy=policy,
                            groups=groups)
        shared = handle.cluster.pod_pool(handle.pod, default_pages=pages)
        return shared.view(handle.app.name,
                           quota=opts.get("quota_pages"),
                           weight=float(opts.get("weight", 1.0)),
                           policy=policy, groups=groups)

    def build_engine(self, handle: "AppHandle") -> ServingEngine:
        opts = handle.app.options
        return ServingEngine(self.build_pool(handle),
                             max_batch=int(opts.get("max_batch", 8)),
                             history=handle.cluster.history)

    def maybe_checkpoint(self, handle: "AppHandle") -> None:
        pass

    def checkpoint(self, handle: "AppHandle", block: bool = True) -> None:
        pass

    def restore(self, handle: "AppHandle") -> int:
        """Restore the latest persisted cut; returns the restart cursor."""
        return 0

    def release(self, handle: "AppHandle") -> None:
        engine = handle.exec_state.get("engine")
        if engine is not None:
            engine.shutdown()      # return pages to the pod's shared pool
        handle.exec_state.clear()


class NullExecutor(Executor):
    """Placement/accounting only -- drives the event-driven simulator."""


class JaxExecutor(Executor):
    """Real execution: jit-compiled training steps / model-backed serving."""

    name = "jax"

    def __init__(self, *, ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 resume: bool = False, seed: int = 0,
                 opt_cfg: Optional[Any] = None,
                 compile_cache: Optional[CompileCache] = None):
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.resume = resume
        self.seed = seed
        self.opt_cfg = opt_cfg
        self.cache = compile_cache or CompileCache()

    def _ckpt_dir(self, handle: "AppHandle") -> Optional[str]:
        """Per-application checkpoint namespace: one executor drives many
        applications, which must not overwrite each other's cuts."""
        if not self.ckpt_dir:
            return None
        import os
        return os.path.join(self.ckpt_dir, handle.app.name.replace("/", "_"))

    # -- binding ------------------------------------------------------------
    def bind(self, handle: "AppHandle") -> None:
        if handle.app.kind == "train":
            self._bind_train(handle)
        else:
            handle.exec_state["engine"] = self.build_engine(handle)

    def _bind_train(self, handle: "AppHandle") -> None:
        import jax

        from repro.checkpoint.checkpointer import AsyncCheckpointer
        from repro.data.pipeline import DataConfig, SyntheticLM
        from repro.models import ImplConfig, build_model
        from repro.training import optimizer as opt
        from repro.training.train_step import make_train_step

        app, plan = handle.app, handle.plan
        cfg, shape = app.config, app.shape
        # reduced CPU runs keep remat off: the ladder's remat choice targets
        # pod HBM budgets, not the smoke-scale footprint
        impl = ImplConfig(remat="none" if app.reduced else plan.remat)
        model = build_model(cfg, impl)
        rng = jax.random.PRNGKey(self.seed)
        params = model.init_params(rng)
        opt_state = opt.init_opt_state(params)
        key = plan_layout_key(cfg.name, shape.name, plan.mesh.name, plan)
        step = self.cache.get_or_compile(
            key, lambda: jax.jit(make_train_step(model, plan, self.opt_cfg)))
        data = SyntheticLM(DataConfig(cfg.vocab_size, shape.seq_len,
                                      shape.global_batch))
        ckpt_dir = self._ckpt_dir(handle)
        ck = AsyncCheckpointer(ckpt_dir, keep=3) if ckpt_dir else None
        handle.exec_state.update(model=model, params=params,
                                 opt_state=opt_state, step=step, data=data,
                                 checkpointer=ck)
        if self.resume:
            handle.cursor = max(handle.cursor, self.restore(handle))

    # -- training -----------------------------------------------------------
    def train_step(self, handle: "AppHandle") -> Dict[str, float]:
        import jax.numpy as jnp

        st = handle.exec_state
        batch = {k: jnp.asarray(v)
                 for k, v in st["data"].batch_at(handle.cursor).items()}
        st["params"], st["opt_state"], m = st["step"](
            st["params"], st["opt_state"], batch)
        return {"loss": float(m["loss"])}

    def maybe_checkpoint(self, handle: "AppHandle") -> None:
        if (self.ckpt_every and handle.exec_state.get("checkpointer")
                and handle.cursor % self.ckpt_every == 0):
            self.checkpoint(handle, block=False)

    def checkpoint(self, handle: "AppHandle", block: bool = True) -> None:
        ck = handle.exec_state.get("checkpointer")
        if ck is None:
            return
        st = handle.exec_state
        ck.save(handle.cursor, {"params": st["params"], "opt": st["opt_state"]},
                extra={"cursor": handle.cursor}, block=block)

    def restore(self, handle: "AppHandle") -> int:
        from repro.checkpoint.checkpointer import (latest_step,
                                                   restore_checkpoint)
        ckpt_dir = self._ckpt_dir(handle)
        if not ckpt_dir or latest_step(ckpt_dir) is None:
            return 0
        st = handle.exec_state
        tree = {"params": st["params"], "opt": st["opt_state"]}
        restored, extra, _ = restore_checkpoint(ckpt_dir, None, tree)
        st["params"], st["opt_state"] = restored["params"], restored["opt"]
        return int(extra.get("cursor", 0))

    # -- serving ------------------------------------------------------------
    default_pool_pages = 128

    def build_engine(self, handle: "AppHandle") -> ServingEngine:
        from repro.serving.model_runner import (KVArrayStore, PagedRunner,
                                                build_runner, kv_shape_key)

        from repro.serving.prefix_cache import PrefixCache

        app = handle.app
        opts = app.options
        max_batch = int(opts.get("max_batch", 4))
        backend = opts.get("backend", "dense")
        use_rings = bool(opts.get("swa_rings", True))
        pool = self.build_pool(handle)
        try:
            kv_store = None
            if (backend == "paged"
                    and getattr(pool, "shared", None) is not None
                    and bool(opts.get("alias_kv", True))
                    and all(k in PagedRunner.SUPPORTED_KINDS
                            for k in app.config.pattern)):
                # physical aliasing: every same-KV-shape paged tenant on
                # this pod reads/writes ONE device page-array set, keyed
                # by shape (mismatched shapes get their own store, i.e.
                # fall back to private arrays; opts['alias_kv']=False
                # opts out explicitly)
                key = kv_shape_key(app.config, pool.physical_pages,
                                   use_rings=use_rings)
                kv_store = pool.shared.kv_store(
                    key, lambda: KVArrayStore(key))
                pool.bind_kv_store(kv_store)
            prefix_cache = None
            if bool(opts.get("prefix_cache", False)) and backend == "paged":
                if kv_store is not None:
                    # pod-global cache: keyed by (kv shape, model, seed)
                    # -- same-weights tenants share cached prefixes, and
                    # the cache's pages return to the POD free list
                    ck = (kv_store.key, app.config.name, self.seed)
                    shared = pool.shared
                    prefix_cache = shared.prefix_cache(
                        ck, lambda: PrefixCache(ck, shared._give))
                    prefix_cache.users.add(app.name)
                else:
                    # private pool (or un-aliased tenant): a private cache
                    # still dedups this app's own prompt overlap.  Evicted
                    # pages must return to whatever free list GRANTED
                    # them: the pod's for a shared-pool view (its own
                    # `free` list is a dead stub -- extending it would
                    # leak the pages from the pod forever), the pool's
                    # own otherwise
                    shared = getattr(pool, "shared", None)
                    free_fn = (shared._give if shared is not None
                               else pool._give)
                    prefix_cache = PrefixCache(
                        (None, app.config.name, self.seed), free_fn)
                pool.prefix_cache = prefix_cache
            elif bool(opts.get("prefix_cache", False)):
                # dense backend: reject loudly inside build_runner below
                prefix_cache = PrefixCache((None,), lambda pages: None)
            runner = build_runner(backend, app.config,
                                  seed=self.seed, max_batch=max_batch,
                                  cache_len=int(opts.get("cache_len", 256)),
                                  pool_pages=pool.physical_pages,
                                  use_rings=use_rings, kv_store=kv_store,
                                  prefix_cache=prefix_cache,
                                  chunk_pages=int(opts.get("chunk_pages", 4)))
        except Exception:
            # the pool view is already registered on the pod: an orphan
            # would dilute every tenant's fair share forever (close also
            # unbinds the kv store, dropping it with its last user)
            close = getattr(pool, "close", None)
            if close is not None:
                close()
            raise
        handle.exec_state.update(model=runner.model, params=runner.params,
                                 runner=runner)
        return ServingEngine(pool, max_batch=max_batch, runner=runner,
                             history=handle.cluster.history)

    def release(self, handle: "AppHandle") -> None:
        ck = handle.exec_state.get("checkpointer")
        if ck is not None:
            ck.wait()
        super().release(handle)
