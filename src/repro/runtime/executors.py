"""Execution backends for the runtime: the simulator and real jax share
ONE submission path and differ only in the executor bound at submit time.

* :class:`NullExecutor` -- no jax, no device state.  Training steps are
  no-ops and serving engines run without step functions: exactly what the
  scheduler-scalability and placement benchmarks need (pure decision
  throughput, like the paper's §6.2 measurement).
* :class:`JaxExecutor` -- builds the model, compiles the step through the
  CompileCache, feeds synthetic data, writes async checkpoints, and runs
  real prefill/decode through the ServingEngine.

Executors keep all per-application state on ``handle.exec_state`` so one
executor instance can drive many applications on one cluster.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, TYPE_CHECKING

from repro.core.compile_cache import CompileCache, plan_layout_key
from repro.runtime.options import ServeOptions
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagePool

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.runtime.cluster import AppHandle
    from repro.serving.router import Replica

DEFAULT_POOL_PAGES = 256


class Executor:
    """Interface the AppHandle lifecycle drives."""

    name = "null"
    default_pool_pages = DEFAULT_POOL_PAGES
    default_max_batch = 8

    def bind(self, handle: "AppHandle") -> None:
        """Materialize executable state for a placed application."""
        if handle.app.kind == "serve":
            self._bind_serve(handle)

    @staticmethod
    def serve_opts(handle: "AppHandle") -> ServeOptions:
        """The app's typed serve surface (directly-constructed
        Applications may still carry a legacy options dict)."""
        so = getattr(handle.app, "serve_options", None)
        if so is not None:
            return so
        return ServeOptions.from_kwargs(handle.app.options or {})

    def _bind_serve(self, handle: "AppHandle") -> None:
        """Serve data plane: a ReplicaSet of engines registered with the
        pod's RequestRouter.  ``exec_state['engine']`` stays the primary
        replica's engine (the stable single-engine surface tests and
        tools already consume)."""
        from repro.serving.router import ReplicaSet
        opts = self.serve_opts(handle)
        rset = ReplicaSet(handle.app.name,
                          lambda idx: self.build_replica(handle, idx),
                          initial=opts.replicas, app_weight=opts.weight,
                          quota_pages=opts.quota_pages
                          if isinstance(opts.quota_pages, int) else None)
        try:
            handle.cluster.router(handle.pod).register(handle.app.name, rset)
        except Exception:
            rset.shutdown()
            raise
        handle.exec_state["replicas"] = rset
        handle.exec_state["engine"] = rset.primary.engine

    def train_step(self, handle: "AppHandle") -> Dict[str, float]:
        return {"loss": 0.0}

    def build_pool(self, handle: "AppHandle",
                   view_name: Optional[str] = None) -> PagePool:
        """The application's KV page pool.

        Default: a quota/weight-scoped *view* onto the pod's single
        :class:`~repro.serving.tenancy.SharedPagePool`, so every serve app
        placed on one pod draws from one physical pool (the paper's
        resource sharing).  ``ServeOptions.private_pool`` opts out into
        the old one-pool-per-app peak provisioning (the benchmark's
        baseline arm).

        Replica views carry suffixed names (``view_name``) but one
        per-app ``history_key``, so N replicas feed one sizing-history
        series instead of fragmenting it.

        When the app serves through the paged backend on a mixed
        global/sliding-window stack, the pool carries the model's
        :class:`~repro.serving.kv_cache.PageGroups` so local-attention
        layers are charged a bounded ring instead of the growing table
        (``swa_rings=False`` opts out, the benchmark's no-ring arm)."""
        opts = self.serve_opts(handle)
        pages = int(opts.pool_pages or self.default_pool_pages)
        groups = None
        if (opts.backend == "paged" and handle.app.config is not None
                and opts.swa_rings):
            from repro.serving.kv_cache import PageGroups
            g = PageGroups.from_config(handle.app.config)
            groups = g if g.local_layers else None
        if opts.private_pool:
            return PagePool(pages, history=handle.cluster.history,
                            app=handle.app.name, policy=opts.policy,
                            groups=groups)
        shared = handle.cluster.pod_pool(handle.pod, default_pages=pages)
        return shared.view(view_name or handle.app.name,
                           quota=opts.quota_pages, weight=opts.weight,
                           policy=opts.policy, groups=groups,
                           history_key=handle.app.name)

    def build_replica(self, handle: "AppHandle", idx: int) -> "Replica":
        from repro.serving.router import Replica, replica_view_name
        opts = self.serve_opts(handle)
        pool = self.build_pool(
            handle, view_name=replica_view_name(handle.app.name, idx))
        eng = ServingEngine(pool,
                            max_batch=opts.max_batch or self.default_max_batch,
                            history=handle.cluster.history)
        return Replica(idx, eng)

    def maybe_checkpoint(self, handle: "AppHandle") -> None:
        pass

    def checkpoint(self, handle: "AppHandle", block: bool = True) -> None:
        pass

    def restore(self, handle: "AppHandle") -> int:
        """Restore the latest persisted cut; returns the restart cursor."""
        return 0

    def release(self, handle: "AppHandle") -> None:
        rset = handle.exec_state.get("replicas")
        if rset is not None:
            handle.cluster.router(handle.pod).unregister(handle.app.name)
            rset.shutdown()    # return pages to the pod's shared pool
        else:
            engine = handle.exec_state.get("engine")
            if engine is not None:
                engine.shutdown()
        handle.exec_state.clear()


class NullExecutor(Executor):
    """Placement/accounting only -- drives the event-driven simulator."""


class JaxExecutor(Executor):
    """Real execution: jit-compiled training steps / model-backed serving."""

    name = "jax"

    def __init__(self, *, ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 resume: bool = False, seed: int = 0,
                 opt_cfg: Optional[Any] = None,
                 compile_cache: Optional[CompileCache] = None):
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.resume = resume
        self.seed = seed
        self.opt_cfg = opt_cfg
        self.cache = compile_cache or CompileCache()

    def _ckpt_dir(self, handle: "AppHandle") -> Optional[str]:
        """Per-application checkpoint namespace: one executor drives many
        applications, which must not overwrite each other's cuts."""
        if not self.ckpt_dir:
            return None
        import os
        return os.path.join(self.ckpt_dir, handle.app.name.replace("/", "_"))

    # -- binding ------------------------------------------------------------
    def bind(self, handle: "AppHandle") -> None:
        if handle.app.kind == "train":
            self._bind_train(handle)
        else:
            self._bind_serve(handle)

    def _bind_train(self, handle: "AppHandle") -> None:
        import jax

        from repro.checkpoint.checkpointer import AsyncCheckpointer
        from repro.data.pipeline import DataConfig, SyntheticLM
        from repro.models import ImplConfig, build_model
        from repro.training import optimizer as opt
        from repro.training.train_step import make_train_step

        app, plan = handle.app, handle.plan
        cfg, shape = app.config, app.shape
        # reduced CPU runs keep remat off: the ladder's remat choice targets
        # pod HBM budgets, not the smoke-scale footprint
        impl = ImplConfig(remat="none" if app.reduced else plan.remat)
        model = build_model(cfg, impl)
        rng = jax.random.PRNGKey(self.seed)
        params = model.init_params(rng)
        opt_state = opt.init_opt_state(params)
        key = plan_layout_key(cfg.name, shape.name, plan.mesh.name, plan)
        step = self.cache.get_or_compile(
            key, lambda: jax.jit(make_train_step(model, plan, self.opt_cfg)))
        data = SyntheticLM(DataConfig(cfg.vocab_size, shape.seq_len,
                                      shape.global_batch))
        ckpt_dir = self._ckpt_dir(handle)
        ck = AsyncCheckpointer(ckpt_dir, keep=3) if ckpt_dir else None
        handle.exec_state.update(model=model, params=params,
                                 opt_state=opt_state, step=step, data=data,
                                 checkpointer=ck)
        if self.resume:
            handle.cursor = max(handle.cursor, self.restore(handle))

    # -- training -----------------------------------------------------------
    def train_step(self, handle: "AppHandle") -> Dict[str, float]:
        import jax.numpy as jnp

        st = handle.exec_state
        batch = {k: jnp.asarray(v)
                 for k, v in st["data"].batch_at(handle.cursor).items()}
        st["params"], st["opt_state"], m = st["step"](
            st["params"], st["opt_state"], batch)
        return {"loss": float(m["loss"])}

    def maybe_checkpoint(self, handle: "AppHandle") -> None:
        if (self.ckpt_every and handle.exec_state.get("checkpointer")
                and handle.cursor % self.ckpt_every == 0):
            self.checkpoint(handle, block=False)

    def checkpoint(self, handle: "AppHandle", block: bool = True) -> None:
        ck = handle.exec_state.get("checkpointer")
        if ck is None:
            return
        st = handle.exec_state
        ck.save(handle.cursor, {"params": st["params"], "opt": st["opt_state"]},
                extra={"cursor": handle.cursor}, block=block)

    def restore(self, handle: "AppHandle") -> int:
        from repro.checkpoint.checkpointer import (latest_step,
                                                   restore_checkpoint)
        ckpt_dir = self._ckpt_dir(handle)
        if not ckpt_dir or latest_step(ckpt_dir) is None:
            return 0
        st = handle.exec_state
        tree = {"params": st["params"], "opt": st["opt_state"]}
        restored, extra, _ = restore_checkpoint(ckpt_dir, None, tree)
        st["params"], st["opt_state"] = restored["params"], restored["opt"]
        return int(extra.get("cursor", 0))

    # -- serving ------------------------------------------------------------
    default_pool_pages = 128
    default_max_batch = 4

    def build_replica(self, handle: "AppHandle", idx: int) -> "Replica":
        from repro.serving.model_runner import (KVArrayStore, PagedRunner,
                                                build_runner, kv_shape_key)
        from repro.serving.prefix_cache import PrefixCache
        from repro.serving.router import Replica, replica_view_name

        app = handle.app
        opts = self.serve_opts(handle)
        max_batch = opts.max_batch or self.default_max_batch
        # both backends pad decode to the runner's build-time batch, so a
        # batch-scaling policy gets its headroom baked into the compile
        # shape up front: the engine's admission width then moves within
        # it with zero retraces
        runner_batch = max_batch
        if opts.scale is not None and opts.scale.batch_max is not None:
            runner_batch = max(runner_batch, opts.scale.batch_max)
        backend = opts.backend
        use_rings = opts.swa_rings
        pool = self.build_pool(
            handle, view_name=replica_view_name(app.name, idx))
        try:
            kv_store = None
            if (backend == "paged"
                    and getattr(pool, "shared", None) is not None
                    and opts.alias_kv
                    and all(k in PagedRunner.SUPPORTED_KINDS
                            for k in app.config.pattern)):
                # physical aliasing: every same-KV-shape paged tenant on
                # this pod -- and every replica of one app -- reads/writes
                # ONE device page-array set, keyed by shape (mismatched
                # shapes get their own store, i.e. fall back to private
                # arrays; alias_kv=False opts out explicitly)
                key = kv_shape_key(app.config, pool.physical_pages,
                                   use_rings=use_rings)
                kv_store = pool.shared.kv_store(
                    key, lambda: KVArrayStore(key))
                pool.bind_kv_store(kv_store)
            prefix_cache = None
            if opts.prefix_cache:
                if kv_store is not None:
                    # pod-global cache: keyed by (kv shape, model, seed)
                    # -- same-weights tenants share cached prefixes, and
                    # the cache's pages return to the POD free list
                    ck = (kv_store.key, app.config.name, self.seed)
                    shared = pool.shared
                    prefix_cache = shared.prefix_cache(
                        ck, lambda: PrefixCache(ck, shared._give))
                    prefix_cache.users.add(app.name)
                else:
                    # private pool (or un-aliased tenant): a private cache
                    # still dedups this app's own prompt overlap.  Evicted
                    # pages must return to whatever free list GRANTED
                    # them: the pod's for a shared-pool view (its own
                    # `free` list is a dead stub -- extending it would
                    # leak the pages from the pod forever), the pool's
                    # own otherwise
                    shared = getattr(pool, "shared", None)
                    free_fn = (shared._give if shared is not None
                               else pool._give)
                    prefix_cache = PrefixCache(
                        (None, app.config.name, self.seed), free_fn)
                pool.prefix_cache = prefix_cache
            runner = build_runner(backend, app.config,
                                  seed=self.seed, max_batch=runner_batch,
                                  cache_len=opts.cache_len,
                                  pool_pages=pool.physical_pages,
                                  use_rings=use_rings, kv_store=kv_store,
                                  prefix_cache=prefix_cache,
                                  chunk_pages=opts.chunk_pages or 4)
        except Exception:
            # the pool view is already registered on the pod: an orphan
            # would dilute every tenant's fair share forever (close also
            # unbinds the kv store, dropping it with its last user)
            close = getattr(pool, "close", None)
            if close is not None:
                close()
            raise
        prim = handle.exec_state.get("runner")
        if idx > 0 and prim is not None and prim.backend == runner.backend:
            # replicas serve one model: alias the primary's weights so a
            # replica costs compute slots, not a second params copy
            runner.params = prim.params
        eng = ServingEngine(pool, max_batch=max_batch, runner=runner,
                            history=handle.cluster.history)
        if idx == 0:
            handle.exec_state.update(model=runner.model,
                                     params=runner.params, runner=runner)
        return Replica(idx, eng, runner=runner)

    def release(self, handle: "AppHandle") -> None:
        ck = handle.exec_state.get("checkpointer")
        if ck is not None:
            ck.wait()
        super().release(handle)
