"""Application: the resource-centric unit users program against.

The paper's core claim is that the *application* -- not a function -- is
what users hand to the platform, and the platform sizes, places, scales,
and recovers it (§2, §4).  An :class:`Application` bundles everything the
platform needs to do that:

* the model/program definition (a built-in ``ModelConfig`` via
  ``get_config``, or a user callable annotated with ``@compute`` /
  ``@data`` / ``@app_limit``),
* the invocation class (a ``ShapeConfig``: train / prefill / decode at a
  given sequence length and batch),
* the spending cap (``AppLimits``), and
* workload options the executor reads (steps, requests, batch sizes...).

Applications are descriptions only: nothing touches jax or device state
until a :class:`~repro.runtime.cluster.Cluster` accepts the submission.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Union

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig, get_config)
from repro.configs.reduced import reduced_config
from repro.core import profiles as prof
from repro.core.annotations import AppLimits, current_app_limits
from repro.core.graph import ResourceGraph, build_resource_graph
from repro.runtime.options import ServeOptions

# CPU smoke-scale invocation classes (same code path, reduced size)
REDUCED_SHAPES = {
    "train": ShapeConfig("reduced_train", "train", 64, 8),
    "prefill": ShapeConfig("reduced_prefill", "prefill", 64, 4),
    "decode": ShapeConfig("reduced_decode", "decode", 64, 4),
}


def _resolve_config(config: Union[str, ModelConfig]) -> ModelConfig:
    return get_config(config) if isinstance(config, str) else config


@dataclass
class Application:
    """One bulky application: a model/program plus its invocation class."""

    name: str
    kind: str                              # train | serve
    config: Optional[ModelConfig] = None   # None for synthetic (sim-only)
    shape: Optional[ShapeConfig] = None
    limits: AppLimits = field(default_factory=AppLimits)
    reduced: bool = False
    demand_bytes: Optional[int] = None     # explicit footprint override
    demand_chips: int = 1
    options: Dict[str, Any] = field(default_factory=dict)
    #: typed serve surface; ``options`` mirrors it for serve apps
    serve_options: Optional[ServeOptions] = None
    _graph: Optional[ResourceGraph] = field(default=None, repr=False)

    # -- constructors -------------------------------------------------------
    @classmethod
    def train(cls, config: Union[str, ModelConfig], *,
              shape: Union[str, ShapeConfig] = "train_4k",
              reduced: bool = False, name: Optional[str] = None,
              limits: Optional[AppLimits] = None,
              **options) -> "Application":
        cfg = _resolve_config(config)
        sh = SHAPES[shape] if isinstance(shape, str) else shape
        if reduced:
            cfg = reduced_config(cfg)
            sh = REDUCED_SHAPES["train"]
        # stable default identity: history-based sizing keys on the app name
        return cls(name or f"{cfg.name}:train", "train",
                   cfg, sh, limits or AppLimits(), reduced, options=options)

    @classmethod
    def serve(cls, config: Union[str, ModelConfig], *,
              shape: Union[str, ShapeConfig] = "decode_32k",
              reduced: bool = False, name: Optional[str] = None,
              limits: Optional[AppLimits] = None,
              serve: Optional[ServeOptions] = None,
              **options) -> "Application":
        cfg = _resolve_config(config)
        sh = SHAPES[shape] if isinstance(shape, str) else shape
        if reduced:
            cfg = reduced_config(cfg)
            sh = REDUCED_SHAPES["decode"]
        if serve is not None and options:
            raise TypeError(
                "Application.serve: pass serve=ServeOptions(...) OR legacy "
                f"keyword options, not both (got serve= plus "
                f"{sorted(options)})")
        if serve is None:
            if options:
                warnings.warn(
                    "Application.serve(**options) keyword options are "
                    "deprecated and will be removed next release; pass "
                    "serve=ServeOptions(" +
                    ", ".join(f"{k}=..." for k in sorted(options)) + ")",
                    DeprecationWarning, stacklevel=2)
            serve = ServeOptions.from_kwargs(options)
        return cls(name or f"{cfg.name}:serve", "serve",
                   cfg, sh, limits or AppLimits(), reduced,
                   options=serve.asdict(), serve_options=serve)

    @classmethod
    def from_callable(cls, app_fn: Callable[[], ModelConfig], *,
                      kind: str = "train",
                      shape: Union[str, ShapeConfig] = "train_4k",
                      serve: Optional[ServeOptions] = None,
                      **options) -> "Application":
        """Build from an annotated user 'source program'.

        ``app_fn`` is a callable (typically decorated with ``@compute`` /
        ``@app_limit``) returning the program's ``ModelConfig``; its
        annotations become the application's components and spending cap."""
        cfg = app_fn()
        limits = getattr(app_fn, "__app_limits__", None) or current_app_limits()
        comp = getattr(app_fn, "__component__", None)
        name = (comp or {}).get("name") or getattr(
            app_fn, "__name__", "user-app")
        sh = SHAPES[shape] if isinstance(shape, str) else shape
        if kind == "train":
            if serve is not None:
                raise TypeError("from_callable: serve=ServeOptions is only "
                                "valid with kind='serve'")
            return cls.train(cfg, shape=sh, name=name, limits=limits,
                             **options)
        return cls.serve(cfg, shape=sh, name=name, limits=limits,
                         serve=serve, **options)

    @classmethod
    def synthetic(cls, name: str, kind: str, demand_bytes: int,
                  demand_chips: int = 1) -> "Application":
        """Simulation-only application with an explicit footprint (used by
        the scheduler benchmarks: no model, no graph, no jax)."""
        return cls(name, kind, demand_bytes=demand_bytes,
                   demand_chips=demand_chips)

    # -- resource profile ---------------------------------------------------
    def resource_graph(self) -> Optional[ResourceGraph]:
        """The paper's IR for this application (cached; None if synthetic)."""
        if self.config is None:
            return None
        if self._graph is None:
            self._graph = build_resource_graph(self.config, self.shape)
        return self._graph

    def estimate_demand(self) -> int:
        """Proactive footprint estimate in bytes (profiles; pre-history)."""
        if self.demand_bytes is not None:
            return self.demand_bytes
        cfg, shape = self.config, self.shape
        p = prof.param_bytes(cfg)
        if shape.kind == "train":
            return int(p + prof.optimizer_bytes(cfg)
                       + prof.activation_bytes_train(cfg, shape))
        return int(p + prof.kv_cache_bytes(cfg, shape))

    def structural_floor(self) -> int:
        """Bytes that must be resident from the first step regardless of
        history: params (+ optimizer state for training).  History-based
        sizing may shrink the input-dependent share (activations, KV)
        below the proactive estimate, but never below this."""
        if self.config is None:
            return 0
        p = prof.param_bytes(self.config)
        if self.kind == "train":
            return int(p + prof.optimizer_bytes(self.config))
        return int(p)

    def capped_demand(self, demand: int) -> int:
        """Apply the @app_limit spending cap to a demand estimate."""
        if self.limits.max_hbm_bytes is not None:
            demand = min(demand, self.limits.max_hbm_bytes)
        return demand
