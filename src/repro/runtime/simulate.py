"""Event-driven trace replay through the REAL submission path.

The scheduler-scalability benchmark (paper §6.2: 50k invocations/s global,
20k components/s per rack) replays arrival traces through
``Cluster.submit`` / ``AppHandle.release`` with a :class:`NullExecutor` --
the same objects and code path that drive real execution, so the measured
decision throughput is honest about every piece of per-application
bookkeeping the runtime does.
"""

from __future__ import annotations

import heapq
import itertools
import random
import time
from typing import Dict, List, Tuple

from repro.runtime.application import Application
from repro.runtime.cluster import GB, AppHandle, Cluster
from repro.runtime.executors import NullExecutor


def replay_trace(cluster: Cluster,
                 arrivals: List[Tuple[float, Application, float]]) -> Dict:
    """Replay ``(t_arrive, app, duration)`` arrivals.  Returns throughput
    stats.  Applications that queue (insufficient capacity) are completed
    once the scheduler drains them on a later release."""
    seq = itertools.count()
    events: List[Tuple[float, int, str, object]] = []
    for t, app, dur in arrivals:
        heapq.heappush(events, (t, next(seq), "arrive", (app, dur)))
    waiting: List[Tuple[AppHandle, float]] = []
    placed = finished = 0
    wall0 = time.perf_counter()
    while events:
        t, _, kind, payload = heapq.heappop(events)
        if kind == "arrive":
            app, dur = payload
            handle = cluster.submit(app)
            if handle.state == "running":
                placed += 1
                heapq.heappush(events, (t + dur, next(seq), "finish", handle))
            else:
                waiting.append((handle, dur))
        else:
            payload.release()
            finished += 1
            if waiting:  # queue drained inside release: schedule their ends
                still = []
                for handle, dur in waiting:
                    if handle.state == "running":
                        placed += 1
                        heapq.heappush(events,
                                       (t + dur, next(seq), "finish", handle))
                    else:
                        still.append((handle, dur))
                waiting = still
    wall = time.perf_counter() - wall0
    return {
        "placed": placed, "finished": finished,
        "still_pending": len(waiting),
        "wall_s": wall,
        "sched_ops_per_s": (placed + finished) / max(wall, 1e-9),
    }


def measure_cluster_throughput(n_jobs: int = 50_000,
                               num_pods: int = 8) -> Dict:
    """Pure scheduling decisions/second through the runtime API."""
    rnd = random.Random(0)
    arrivals = []
    for i in range(n_jobs):
        demand = rnd.choice([1, 2, 4, 8, 16]) * GB
        app = Application.synthetic(f"app{i % 32}", "serve", demand)
        arrivals.append((i * 1e-6, app, 1e-3))
    cluster = Cluster(num_pods, executor=NullExecutor())
    return replay_trace(cluster, arrivals)
