"""Cluster + AppHandle: the single public submission path.

The lifecycle (paper §4-§5, TPU-adapted)::

    cluster = Cluster(pods=2, mesh=SINGLE_POD, history=..., executor=...)
    handle  = cluster.submit(app)     # size -> place -> materialize -> bind
    handle.run(steps)                 # execute (train loop / serving engine)
    handle.scale_up(bytes)            # runtime data-component growth
    handle.park()                     # idle reclamation (KV -> host,
                                      #   pages + bytes released)
    cluster.tick()                    # autoscale reconcile round
    handle.release()                  # free placement, restore capacity

``submit`` performs the platform's side of the resource-centric contract:

1. **sizing** -- proactive profile estimate, refined by the §9.3
   ``solve_init_step`` program over the decayed history of this
   application's past footprints (initial + incremental grant sizes);
2. **placement** -- the two-level scheduler (``GlobalScheduler`` best-fit
   across pods, ``PodScheduler`` component placement within one);
3. **materialization** -- the locality ladder (``materialize``), with
   compile-feedback escalation available via ``handle.escalate``;
4. **execution** -- the bound :class:`~repro.runtime.executors.Executor`
   (NullExecutor for simulation, JaxExecutor for real steps).

Insufficient capacity queues the application (``handle.state ==
"pending"``); releasing other applications drains the queue and the
handle binds lazily on its first step.
"""

from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional, TYPE_CHECKING, Tuple, Union

from repro.checkpoint.recovery import StragglerWatchdog, elastic_replan
from repro.core.history import HistoryStore
from repro.core.materializer import (MESHES, SINGLE_POD, MeshSpec, Plan,
                                     escalate, materialize)
from repro.core.scheduler import GlobalScheduler, Job, PodState
from repro.core.sizing import SizingSolution, solve_init_step
from repro.obs import metrics as obs_metrics
from repro.runtime.application import Application
from repro.runtime.executors import Executor, NullExecutor
from repro.serving.kv_cache import Request

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.serving.router import RequestRouter
    from repro.serving.tenancy import SharedPagePool

GB = 1 << 30
SIZING_QUANTUM = 64 << 20          # 64 MiB allocation granularity


class AppHandle:
    """Live view of one submitted application; drives its lifecycle."""

    def __init__(self, app: Application, job: Job, cluster: "Cluster",
                 sizing: Optional[SizingSolution] = None):
        self.app = app
        self.job = job
        self.cluster = cluster
        self.sizing = sizing
        self.plan: Optional[Plan] = None
        self.exec_state: Dict = {}
        self.bound = False
        self.cursor = 0                 # train steps completed / data cursor
        self.metrics: List[Dict] = []
        self.watchdog = StragglerWatchdog()

    # -- state --------------------------------------------------------------
    @property
    def state(self) -> str:
        return self.job.state

    @property
    def pod(self) -> Optional[str]:
        return self.job.pod

    @property
    def engine(self):
        return self.exec_state.get("engine")

    @property
    def runner(self):
        """The serving backend (ModelRunner) bound to this application."""
        return self.exec_state.get("runner")

    # -- serving data plane (repro.serving.router) ----------------------------
    @property
    def replica_set(self):
        """The app's ReplicaSet (None for train/synthetic apps)."""
        return self.exec_state.get("replicas")

    @property
    def num_replicas(self) -> int:
        """Live replica count (0 while parked: park scales to zero)."""
        if self.parked:
            return 0
        rset = self.replica_set
        if rset is None:
            return 1 if self.engine is not None else 0
        return len(rset.replicas)

    def add_replica(self):
        """Scale out by one engine replica (shared KV arrays + params:
        the cost is compute slots, not memory)."""
        rset = self.replica_set
        if rset is None:
            raise RuntimeError(f"{self.app.name}: no replica set "
                               "(serve applications only)")
        if self.parked:
            raise RuntimeError(f"{self.app.name}: unpark before scaling "
                               "out (a parked app has zero replicas)")
        return rset.add_replica()

    def remove_replica(self) -> Dict:
        """Scale in by one replica; its in-flight requests migrate
        token-identically to a survivor (or requeue)."""
        rset = self.replica_set
        if rset is None:
            raise RuntimeError(f"{self.app.name}: no replica set "
                               "(serve applications only)")
        return rset.remove_replica()

    def set_max_batch(self, n: int) -> int:
        """Set the continuous-batch admission width on every replica
        (clamped to the runners' compile-shape cap); returns the width
        actually applied."""
        rset = self.replica_set
        if rset is None:
            raise RuntimeError(f"{self.app.name}: no replica set "
                               "(serve applications only)")
        return rset.set_max_batch(n)

    @property
    def stats_view(self) -> "StatsView":
        """THE stats surface: cumulative | windowed, replica-aggregated
        (see :class:`repro.serving.stats.StatsView`)."""
        from repro.serving.stats import StatsView
        return StatsView(self)

    def serving_stats(self, since: Optional[Dict] = None) -> Dict:
        """Back-compat shim over :class:`~repro.serving.stats.StatsView`:
        ``serving_stats()`` is ``stats_view.cumulative()`` (a valid
        window marker), ``serving_stats(since=marker)`` is
        ``stats_view.windowed(marker)``."""
        view = self.stats_view
        if since is None:
            return view.cumulative()
        return view.windowed(since)

    def _ensure_bound(self) -> None:
        if self.job.state != "running":
            raise RuntimeError(
                f"{self.app.name}: not placed (state={self.job.state}); "
                "release capacity or wait for the pending queue to drain")
        if self.bound or self.app.config is None:
            return
        self.cluster.executor.bind(self)
        self.bound = True

    # -- execution ----------------------------------------------------------
    def step(self) -> Dict:
        """One unit of progress: a train step or one engine iteration.
        A parked serve app makes no progress (park drained it); submit a
        request or call ``unpark()`` to resume."""
        self._ensure_bound()
        if self.app.kind == "serve" and self.parked:
            return {"alive": False, "stats": self.engine.stats,
                    "parked": True}
        if self.app.kind == "train":
            # perf_counter, NOT time.time(): the serving engine stamps
            # submitted_at/TTFT with perf_counter, and trace timestamps
            # must compose with wall measurements on one monotonic clock
            # (time.time() can step backwards under NTP adjustment)
            t0 = time.perf_counter()
            m = self.cluster.executor.train_step(self)
            wall = time.perf_counter() - t0
            self.cursor += 1
            m["wall_s"] = wall
            m["straggled"] = self.watchdog.observe(self.cursor, wall)
            if self.cluster.history is not None:
                self.cluster.history.observe(self.app.config.name, "train",
                                             "step_wall_s", wall)
            self.cluster.executor.maybe_checkpoint(self)
            self.metrics.append(m)
            return m
        rset = self.replica_set
        if rset is not None and rset.router is not None:
            alive = rset.router.step_app(self.app.name)
        else:
            alive = self.engine.step()
        return {"alive": alive, "stats": self.engine.stats}

    def run(self, steps: Optional[int] = None, *,
            max_steps: int = 1_000_000) -> Dict:
        """Run to completion: N train steps, or drain the serving queue."""
        self._ensure_bound()
        if self.app.kind == "train":
            total = steps if steps is not None else int(
                self.app.options.get("steps", 10))
            while self.cursor < total:
                self.step()
            self.cluster.executor.checkpoint(self)
            losses = [m["loss"] for m in self.metrics]
            return {"steps": self.cursor,
                    "loss_first": losses[0] if losses else None,
                    "loss_last": losses[-1] if losses else None,
                    "straggled": len(self.watchdog.flags)}
        if self.parked:
            self.unpark()
        rset = self.replica_set
        if rset is None or rset.router is None:
            stats = self.engine.run_to_completion(max_steps=max_steps)
            return stats.as_dict()
        # scale-out path: drain the router queue plus every replica;
        # counters aggregate across replicas so the dict keeps the exact
        # shape (and, for one replica, the exact values) of the old path
        from repro.serving.stats import aggregate_engine_stats
        router = rset.router
        t0 = time.perf_counter()
        steps = 0
        while steps < max_steps and router.step_app(self.app.name):
            steps += 1
        wall = time.perf_counter() - t0
        self.engine.stats.wall_s = wall
        agg = aggregate_engine_stats(self)
        agg.wall_s = wall
        return agg.as_dict()

    def submit_request(self, req: Request) -> None:
        """Enqueue one serving request; a parked application is
        transparently unparked first (the paper's warm restart: the
        request lands on a live engine with its KV state restored)."""
        self._ensure_bound()
        if self.parked:
            self.unpark()
        rset = self.replica_set
        if rset is not None and rset.router is not None:
            rset.router.submit(self.app.name, req)
        else:
            self.engine.submit(req)

    # -- runtime scaling (paper §5.1.2) -------------------------------------
    def scale_up(self, extra_bytes: int) -> bool:
        """Grow this application's footprint (consumes its reservation)."""
        return self.cluster.scheduler.scale_up(self.job, int(extra_bytes))

    def scale_down(self, release_bytes: int) -> int:
        return self.cluster.scheduler.scale_down(self.job, int(release_bytes))

    # -- idle parking (repro.autoscale) --------------------------------------
    @property
    def parked(self) -> bool:
        return self.exec_state.get("parked") is not None

    def park(self) -> Dict:
        """Reclaim this idle serve app's resources: KV drained to host
        (checkpointer array format), pool pages and scheduler bytes
        released.  Returns the reclamation receipt."""
        from repro.autoscale.parking import park_app
        return park_app(self)

    def unpark(self) -> Dict:
        """Warm restart from a parked snapshot (also triggered
        implicitly by ``submit_request``/``run``)."""
        from repro.autoscale.parking import unpark_app
        return unpark_app(self)

    # -- materialization feedback / recovery --------------------------------
    def _rebind(self) -> None:
        """Drop executable state (quiescing in-flight checkpoints), rebind
        under the current plan, and restore the latest persisted cut."""
        was_bound = self.bound
        self.cluster.executor.release(self)
        self.bound = False
        if was_bound:
            self._ensure_bound()
            self.cursor = self.cluster.executor.restore(self)

    def escalate(self, measured_bytes: int) -> bool:
        """Compile-feedback escalation: move one rung up the ladder."""
        nxt = escalate(self.plan, self.app.config, self.app.shape,
                       measured_bytes)
        if nxt is None:
            return False
        self.plan = nxt
        self._rebind()
        return True

    def checkpoint(self, block: bool = True) -> None:
        self.cluster.executor.checkpoint(self, block=block)

    def recover(self, mesh: Optional[MeshSpec] = None) -> int:
        """Re-materialize (possibly on a different mesh) and restore the
        latest persisted cut.  Returns the restart cursor."""
        mesh = mesh or self.cluster.mesh
        self.plan = elastic_replan(self.app.config, self.app.shape, mesh,
                                   history=self.cluster.history)
        self.bound = True      # recover may be called on a fresh handle too
        self._rebind()
        return self.cursor

    def release(self) -> None:
        self.cluster.release(self)


class Cluster:
    """Resource-centric entry point: owns pods, scheduler, and executor."""

    def __init__(self, pods: Union[int, List[PodState]] = 2, *,
                 mesh: Union[str, MeshSpec] = SINGLE_POD,
                 history: Optional[HistoryStore] = None,
                 executor: Optional[Executor] = None,
                 pool_pages: Optional[int] = None):
        self.mesh = MESHES[mesh] if isinstance(mesh, str) else mesh
        if isinstance(pods, int):
            pods = [PodState(f"pod{i}", self.mesh.num_devices,
                             self.mesh.hbm_per_device) for i in range(pods)]
        self.scheduler = GlobalScheduler(pods, history)
        self.history = history
        self.executor = executor or NullExecutor()
        self.handles: Dict[str, AppHandle] = {}
        self._job_ids = itertools.count()
        # per-pod physical KV pools (multi-tenant serving); sized by
        # ``pool_pages`` when given, else by the first tenant's request
        self.pool_pages = pool_pages
        self._pod_pools: Dict[str, "SharedPagePool"] = {}
        # per-pod front-end request routers (scale-out serving data
        # plane); created lazily like the pools
        self._routers: Dict[str, "RequestRouter"] = {}
        # the autoscale control plane (repro.autoscale); opt-in via
        # enable_autoscale(), driven by tick()
        self.autoscaler = None

    def pod_pool(self, pod: str, *, default_pages: int = 256
                 ) -> "SharedPagePool":
        """The pod's single shared KV page pool (created lazily).  Every
        serve application placed on ``pod`` gets a quota/weight view onto
        this one physical pool unless it opts into a private pool."""
        from repro.serving.tenancy import SharedPagePool
        sp = self._pod_pools.get(pod)
        if sp is None:
            sp = SharedPagePool(self.pool_pages or default_pages,
                                history=self.history)
            self._pod_pools[pod] = sp
        return sp

    def router(self, pod: str) -> "RequestRouter":
        """The pod's front-end request router (created lazily).  Every
        serve application placed on ``pod`` registers its ReplicaSet
        here; ``submit_request`` enqueues into the router, which spreads
        admissions across the app's replicas (join-shortest-queue)."""
        from repro.serving.router import RequestRouter
        rt = self._routers.get(pod)
        if rt is None:
            rt = RequestRouter(pod)
            self._routers[pod] = rt
        return rt

    # -- the control plane (repro.autoscale) ---------------------------------
    def enable_autoscale(self, *, ttft_target_s: Optional[float] = None,
                         denial_target_per_s: float = 0.5,
                         idle_park_s: float = 60.0, **controller_kw):
        """Turn on the autoscale control plane.  Every serve application
        (already running or submitted later) is attached with the stock
        policy chain -- target tracking on TTFT/denial-rate, idle
        parking, and pod-level quota rebalancing -- unless
        ``make_policies`` overrides it.  Drive it with ``tick()``."""
        from repro.autoscale.controller import AutoscaleController
        from repro.autoscale.policy import default_policies
        if "make_policies" not in controller_kw:
            def _mk(handle=None):
                # per-app chain: a ScalePolicy on the app's ServeOptions
                # adds replica/batch scalers + predictive unpark
                scale = None
                if handle is not None and handle.app.serve_options is not None:
                    scale = handle.app.serve_options.scale
                return default_policies(
                    ttft_target_s=ttft_target_s,
                    denial_target_per_s=denial_target_per_s,
                    idle_park_s=idle_park_s,
                    scale=scale)
            controller_kw["make_policies"] = _mk
        self.autoscaler = AutoscaleController(self, **controller_kw)
        for h in self.handles.values():
            self.autoscaler.attach(h)
        return self.autoscaler

    def tick(self, now: Optional[float] = None) -> List[Dict]:
        """One control-plane reconcile round (no-op until
        ``enable_autoscale``).  ``now`` is injectable for event-driven
        replay; defaults to the wall clock."""
        if self.autoscaler is None:
            return []
        return self.autoscaler.tick(now)

    # -- sizing (paper §9.3) -------------------------------------------------
    def size(self, app: Application) -> Tuple[int, Optional[SizingSolution]]:
        """Initial footprint: history-solved init when available, else the
        proactive profile estimate; always capped by @app_limit."""
        demand = app.estimate_demand()
        sol = None
        if self.history is not None:
            h = self.history.get(app.name, "job", "bytes")
            if h is not None and h.count:
                sol = solve_init_step(h.samples(),
                                      quantum=float(SIZING_QUANTUM))
                if sol.feasible and sol.init > 0:
                    demand = max(int(sol.init), app.structural_floor())
        return app.capped_demand(demand), sol

    # -- lifecycle ----------------------------------------------------------
    def submit(self, app: Application, *,
               overrides: Optional[Dict] = None) -> AppHandle:
        demand, sizing = self.size(app)
        job = Job(f"job{next(self._job_ids)}", app.name, app.kind,
                  demand, app.demand_chips)
        handle = AppHandle(app, job, self, sizing=sizing)
        self.scheduler.submit(job)
        if app.config is not None:
            handle.plan = materialize(app.config, app.shape, self.mesh,
                                      history=self.history,
                                      overrides=overrides)
            if job.state == "running":
                try:
                    handle._ensure_bound()
                except Exception:
                    # bind failed (e.g. duplicate serve name, unsupported
                    # backend): the placed job would otherwise hold pod
                    # bytes forever with no handle to release it through
                    handle.exec_state.clear()
                    self.scheduler.finish(job)
                    raise
        self.handles[job.job_id] = handle
        if self.autoscaler is not None:
            self.autoscaler.attach(handle)
        return handle

    def release(self, handle: AppHandle) -> None:
        if self.autoscaler is not None:
            self.autoscaler.detach(handle)
        if handle.job.state == "pending":
            self.scheduler.cancel(handle.job)
        elif handle.job.state == "running":
            self.executor.release(handle)
            self.scheduler.finish(handle.job)
        handle.bound = False
        self.handles.pop(handle.job.job_id, None)

    # -- introspection -------------------------------------------------------
    def capacity(self) -> Dict[str, Dict[str, int]]:
        """Exact per-pod accounting snapshot (free / reserved / running)."""
        return {name: {"free_bytes": ps.pod.free_bytes,
                       "reserved_bytes": ps.pod.reserved_bytes,
                       "running": len(ps.pod.running)}
                for name, ps in self.scheduler.pods.items()}

    @property
    def running(self) -> List[AppHandle]:
        return [h for h in self.handles.values() if h.state == "running"]

    @property
    def pending(self) -> List[AppHandle]:
        return [h for h in self.handles.values() if h.state == "pending"]
