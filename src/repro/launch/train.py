"""Production training driver.

On a real TPU pod:   python -m repro.launch.train --arch mistral-nemo-12b
On this CPU host:    add --reduced to run a smoke-scale config with the
                     SAME code path (materializer, checkpoints, watchdog).

The driver owns the full lifecycle: materialize -> (pre)compile via the
compile cache -> train with async checkpoints at graph cuts -> straggler
watchdog -> crash recovery with elastic re-materialization."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,
                                           restore_checkpoint)
from repro.checkpoint.recovery import StragglerWatchdog
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.core.compile_cache import CompileCache, plan_layout_key
from repro.core.history import HistoryStore
from repro.core.materializer import MESHES, materialize
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import ImplConfig, build_model
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/zenix_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke scale (same code path)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh_spec = MESHES[args.mesh]
    history = HistoryStore("artifacts/history")
    plan = materialize(cfg, shape, mesh_spec, history=history)
    print(f"[plan] {plan.describe()}")

    if args.reduced:
        from tests.conftest import reduced_config  # same reduction recipe
        cfg = reduced_config(cfg)
        shape = ShapeConfig("reduced", "train", 64, 8)

    model = build_model(cfg, ImplConfig(
        remat=plan.remat if not args.reduced else "none"))
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    opt_state = opt.init_opt_state(params)
    step_plan = plan if not args.reduced else materialize(
        cfg, shape, mesh_spec, overrides={"microbatch": 1, "remat": "none"})
    cache = CompileCache()
    key = plan_layout_key(args.arch, args.shape, args.mesh, step_plan)
    step = cache.get_or_compile(
        key, lambda: jax.jit(make_train_step(model, step_plan)))

    start = 0
    ck = AsyncCheckpointer(args.ckpt_dir, keep=3)
    if args.resume and latest_step(args.ckpt_dir) is not None:
        tree = {"params": params, "opt": opt_state}
        restored, extra, s = restore_checkpoint(args.ckpt_dir, None, tree)
        params, opt_state = restored["params"], restored["opt"]
        start = extra["cursor"]
        print(f"[resume] from step {start}")

    data = SyntheticLM(DataConfig(cfg.vocab_size, shape.seq_len,
                                  shape.global_batch))
    wd = StragglerWatchdog()
    for i in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        wall = time.time() - t0
        history.observe(args.arch, "train", "step_wall_s", wall)
        if wd.observe(i, wall):
            print(f"[watchdog] step {i} straggled: {wall:.2f}s")
        if (i + 1) % args.ckpt_every == 0:
            ck.save(i + 1, {"params": params, "opt": opt_state},
                    extra={"cursor": i + 1})
        if i % 10 == 0:
            print(f"step {i}: loss={float(m['loss']):.4f} ({wall:.2f}s)")
    ck.wait()
    history.save()
    print("[done]")


if __name__ == "__main__":
    main()
