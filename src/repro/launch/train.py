"""Production training driver, on the resource-centric runtime API.

On a real TPU pod:   python -m repro.launch.train --arch mistral-nemo-12b
On this CPU host:    add --reduced to run a smoke-scale config with the
                     SAME code path (sizing, placement, materialization,
                     checkpoints, watchdog).

The driver no longer hand-wires materialize -> CompileCache -> Checkpointer:
it describes the application and submits it; the Cluster sizes it from
history (§9.3), places it (two-level scheduler), materializes it (locality
ladder), and the JaxExecutor runs the compiled step loop with async
checkpoints and crash recovery."""

from __future__ import annotations

import argparse

from repro.core.history import HistoryStore
from repro.core.materializer import MESHES
from repro.runtime import Application, Cluster, JaxExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/zenix_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke scale (same code path)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    history = HistoryStore("artifacts/history")
    app = Application.train(args.arch, shape=args.shape,
                            reduced=args.reduced, steps=args.steps)
    cluster = Cluster(pods=1, mesh=MESHES[args.mesh], history=history,
                      executor=JaxExecutor(ckpt_dir=args.ckpt_dir,
                                           ckpt_every=args.ckpt_every,
                                           resume=args.resume))
    handle = cluster.submit(app)
    print(f"[plan] {handle.plan.describe()}")
    print(f"[placed] pod={handle.pod} "
          f"demand={handle.job.demand_bytes / 2**30:.2f} GiB")
    if handle.cursor:
        print(f"[resume] from step {handle.cursor}")

    while handle.cursor < args.steps:
        m = handle.step()
        i = handle.cursor - 1
        if m["straggled"]:
            print(f"[watchdog] step {i} straggled: {m['wall_s']:.2f}s")
        if i % 10 == 0:
            print(f"step {i}: loss={m['loss']:.4f} ({m['wall_s']:.2f}s)")
    handle.checkpoint()
    handle.release()
    history.save()
    print("[done]")


if __name__ == "__main__":
    main()
