"""Production serving driver: continuous batching + paged KV + history
sizing, parameterized by (arch, mesh).  --reduced serves a smoke-scale
model on CPU through the identical engine code path."""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import SHAPES, get_config
from repro.core.history import HistoryStore
from repro.core.materializer import MESHES, materialize
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import (PagePool, Request,
                                    pool_pages_for_budget)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--policy", default="history",
                    choices=["history", "fixed", "peak"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh_spec = MESHES[args.mesh]
    shape = SHAPES["decode_32k"]
    history = HistoryStore("artifacts/history")
    plan = materialize(cfg, shape, mesh_spec, history=history)
    print(f"[plan] kv_shard_heads={plan.kv_shard_heads} "
          f"kv_shard_seq={plan.kv_shard_seq} batch_axes={plan.batch_axes}")

    # KV budget: HBM left after weights on the serving slice
    from repro.core import profiles as prof
    kv_budget = int(mesh_spec.hbm_per_device * mesh_spec.num_devices * 0.6
                    - prof.param_bytes(cfg))
    pages = pool_pages_for_budget(max(kv_budget, 1 << 30), cfg.num_layers,
                                  cfg.kv_dim)
    pool = PagePool(pages, history=history, app=args.arch,
                    policy=args.policy)
    eng = ServingEngine(pool, max_batch=args.max_batch, history=history)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(f"r{i}", int(rng.integers(64, 4096)),
                           int(rng.integers(16, 256))))
    stats = eng.run_to_completion(max_steps=1_000_000)
    print(f"[done] completed={stats.completed} "
          f"tokens={stats.tokens_generated} "
          f"decode_steps={stats.decode_steps} preempted={stats.preempted}")
    print(f"[pool] pages={pages} peak_util={pool.utilization:.2f} "
          f"scaleups={pool.stats['scaleups']} denials={pool.stats['denials']}")
    sz = pool.sizing()
    print(f"[sizing/{args.policy}] init={sz.init:.0f} step={sz.step:.0f}")
    history.save()


if __name__ == "__main__":
    main()
