"""Production serving driver, on the resource-centric runtime API.

Default mode sizes/places the serving application and drives the
continuous-batching engine through the NullExecutor (pure admission /
paging / sizing behaviour, no model).  ``--reduced`` binds the JaxExecutor
instead: a smoke-scale model runs real prefill + batched decode through
the IDENTICAL submission path."""

from __future__ import annotations

import argparse

import numpy as np

from repro import obs
from repro.configs import get_config
from repro.core import profiles as prof
from repro.core.history import HistoryStore
from repro.core.materializer import MESHES
from repro.runtime import Application, Cluster, JaxExecutor, NullExecutor
from repro.runtime.options import ScalePolicy, ServeOptions
from repro.serving.kv_cache import Request, pool_pages_for_budget


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--policy", default="history",
                    choices=["history", "fixed", "peak"])
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "paged"],
                    help="serving ModelRunner (paged = KV in pool pages, "
                         "decode via the paged-attention kernel)")
    ap.add_argument("--private-pool", action="store_true",
                    help="opt out of the pod-shared page pool")
    ap.add_argument("--no-swa-rings", action="store_true",
                    help="paged backend: charge sliding-window layers "
                         "growing page tables instead of bounded rings "
                         "(accounting baseline; tokens are identical)")
    ap.add_argument("--no-alias-kv", action="store_true",
                    help="paged backend: give this tenant its own "
                         "pool-sized device KV arrays instead of "
                         "aliasing the pod's shared same-shape array "
                         "set (benchmark baseline; tokens identical)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged backend: refcounted copy-on-write prefix "
                         "cache -- repeated prompt prefixes reuse cached "
                         "KV pages and prefill computes only the suffix "
                         "(rejected on dense: no shareable page identity)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the front-end request "
                         "router (replicas share the pod pool and, on "
                         "the paged backend, one KV array set + params)")
    ap.add_argument("--max-replicas", type=int, default=None,
                    help="let the autoscale control plane move the "
                         "replica count up to this bound "
                         "(target-tracking on windowed queue depth)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="stream Prometheus metrics on this port for "
                         "the run's duration (0 = ephemeral; implies "
                         "metrics recording)")
    ap.add_argument("--reduced", action="store_true",
                    help="real smoke-scale model via the JaxExecutor")
    ap.add_argument("--autoscale", action="store_true",
                    help="drive the repro.autoscale control plane: two "
                         "bursts with an idle gap; the app is parked "
                         "between them and transparently unparked")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record the full request-lifecycle trace and "
                         "write it here: .jsonl -> one event per line, "
                         "anything else -> Chrome/Perfetto trace JSON "
                         "(summarize with `python -m repro.obs PATH`)")
    ap.add_argument("--metrics-dump", action="store_true",
                    help="record latency histograms and print the "
                         "Prometheus text exposition at the end")
    args = ap.parse_args()
    if args.backend != "dense" and not args.reduced:
        ap.error("--backend needs --reduced: the default arm serves through "
                 "the NullExecutor (no model, no kernel path)")
    if args.prefix_cache and args.backend != "paged":
        ap.error("--prefix-cache needs --backend paged: the dense cache "
                 "has no page identity to share across requests")

    tracer = obs.enable() if args.trace else None
    if args.metrics_dump or args.metrics_port is not None:
        obs.enable_metrics()
    metrics_srv = None
    if args.metrics_port is not None:
        metrics_srv = obs.serve_metrics(port=args.metrics_port)
        print(f"[metrics] http://127.0.0.1:{metrics_srv.port}/metrics")

    cfg = get_config(args.arch)
    mesh_spec = MESHES[args.mesh]
    history = HistoryStore("artifacts/history")

    scale = None
    if args.max_replicas is not None:
        scale = ScalePolicy(min_replicas=1, max_replicas=args.max_replicas)
    try:
        if args.reduced:
            executor = JaxExecutor()
            opts = ServeOptions(backend=args.backend,
                                max_batch=min(args.max_batch, 4),
                                pool_pages=128, policy=args.policy,
                                replicas=args.replicas,
                                swa_rings=not args.no_swa_rings,
                                alias_kv=not args.no_alias_kv,
                                prefix_cache=args.prefix_cache,
                                private_pool=args.private_pool,
                                scale=scale)
            app = Application.serve(args.arch, reduced=True, serve=opts)
            prompt_rng = (8, 64)
            max_new = 16
        else:
            # KV budget: HBM left after weights on the serving slice
            kv_budget = int(mesh_spec.hbm_per_device
                            * mesh_spec.num_devices * 0.6
                            - prof.param_bytes(cfg))
            pages = pool_pages_for_budget(max(kv_budget, 1 << 30),
                                          cfg.num_layers, cfg.kv_dim)
            executor = NullExecutor()
            opts = ServeOptions(max_batch=args.max_batch, pool_pages=pages,
                                policy=args.policy,
                                replicas=args.replicas,
                                private_pool=args.private_pool,
                                scale=scale)
            app = Application.serve(args.arch, shape="decode_32k",
                                    serve=opts)
            prompt_rng = (64, 4096)
            max_new = 256
    except ValueError as e:              # typed-options cross-field rules
        ap.error(str(e))

    cluster = Cluster(pods=1, mesh=mesh_spec, history=history,
                      executor=executor)
    handle = cluster.submit(app)
    print(f"[plan] kv_shard_heads={handle.plan.kv_shard_heads} "
          f"kv_shard_seq={handle.plan.kv_shard_seq} "
          f"batch_axes={handle.plan.batch_axes}")
    print(f"[placed] pod={handle.pod} "
          f"demand={handle.job.demand_bytes / 2**30:.2f} GiB")

    rng = np.random.default_rng(0)
    if args.autoscale:
        cluster.enable_autoscale(idle_park_s=3.0, confirm_ticks=1)
        half = max(args.requests // 2, 1)
        for i in range(half):
            handle.submit_request(Request(f"r{i}",
                                          int(rng.integers(*prompt_rng)),
                                          int(rng.integers(16, max_new + 1))))
        handle.run(max_steps=1_000_000)
        for t in range(6):              # idle ticks: the parker fires
            cluster.tick(now=float(t))
        parks = [a for a in cluster.autoscaler.log if a["action"] == "park"]
        if parks:
            print(f"[autoscale] parked after idle: "
                  f"freed_pages={parks[-1]['freed_pages']} "
                  f"freed_bytes={parks[-1]['freed_bytes']}")
        print(f"[autoscale] parked={handle.parked} "
              f"pod_free={cluster.capacity()[handle.pod]['free_bytes']}")
        for i in range(half, args.requests):   # burst 2: transparent unpark
            handle.submit_request(Request(f"r{i}",
                                          int(rng.integers(*prompt_rng)),
                                          int(rng.integers(16, max_new + 1))))
        print(f"[autoscale] unparked on submit: parked={handle.parked}")
        stats = handle.run(max_steps=1_000_000)
    else:
        for i in range(args.requests):
            handle.submit_request(Request(f"r{i}",
                                          int(rng.integers(*prompt_rng)),
                                          int(rng.integers(16, max_new + 1))))
        stats = handle.run(max_steps=1_000_000)
    pool = handle.engine.pool
    if args.replicas > 1 or args.max_replicas is not None:
        rstats = handle.serving_stats().get("router", {})
        print(f"[router] replicas={handle.num_replicas} "
              f"dispatched={rstats.get('dispatched', 0)} "
              f"added={rstats.get('replicas_added', 0)} "
              f"removed={rstats.get('replicas_removed', 0)}")
    print(f"[done] completed={stats['completed']} "
          f"tokens={stats['tokens_generated']} "
          f"decode_steps={stats['decode_steps']} "
          f"preempted={stats['preempted']} "
          f"mean_ttft={stats['mean_ttft_s'] * 1e3:.2f}ms "
          f"mean_decode_step={stats['mean_decode_step_s'] * 1e3:.2f}ms")
    print(f"[pool] pages={pool.num_pages} peak_util={pool.utilization:.2f} "
          f"scaleups={pool.stats['scaleups']} "
          f"denials={pool.stats['denials']}")
    sstats = handle.serving_stats()
    if "shared_pool" in sstats:
        sp = sstats["shared_pool"]
        print(f"[pod-pool] pages={sp['num_pages']} "
              f"util={sp['utilization']:.2f} "
              f"cross_app_preempt={sp['cross_app_preemptions']}")
    sz = pool.sizing()
    print(f"[sizing/{args.policy}] init={sz.init:.0f} step={sz.step:.0f}")
    if tracer is not None:
        meta = {"arch": args.arch, "backend": args.backend,
                "requests": args.requests}
        if args.trace.endswith(".jsonl"):
            n = obs.write_jsonl(tracer, args.trace)
        else:
            n = obs.write_chrome_trace(tracer, args.trace, extra_meta=meta)
        print(f"[trace] {n} events -> {args.trace} "
              f"(dropped={tracer.dropped}; summarize: "
              f"python -m repro.obs {args.trace})")
        obs.disable()
    if args.metrics_dump:
        print("[metrics]")
        print(obs.current_metrics().render(), end="")
    if metrics_srv is not None:
        metrics_srv.stop()
    if args.metrics_dump or args.metrics_port is not None:
        obs.disable_metrics()
    handle.release()
    history.save()


if __name__ == "__main__":
    main()
