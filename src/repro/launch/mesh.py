"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older jax has Auto-only meshes
    AxisType = None

from repro.core.materializer import MESHES, MeshSpec


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh_from_spec(spec: MeshSpec) -> Mesh:
    return _make_mesh(spec.shape, spec.axes)


def mesh_spec(name: str) -> MeshSpec:
    return MESHES[name]


def make_local_mesh(axes: Tuple[str, ...] = ("data", "model"),
                    shape: Optional[Tuple[int, ...]] = None) -> Mesh:
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n,) + (1,) * (len(axes) - 1)
    return _make_mesh(shape, axes)
