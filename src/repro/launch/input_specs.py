"""ShapeDtypeStruct stand-ins for every model input per (arch x shape).

No device allocation: the dry-run lowers against these structs.  Modality
frontends are stubs per the assignment: whisper gets precomputed frame
embeddings (B, 1500, D); phi-3-vision gets CLIP patch features (B, 576,
1024) and a correspondingly shorter text segment within the seq budget.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    n_img = cfg.num_image_tokens if cfg.family == "vlm" else 0
    s_text = s - n_img
    out = {
        "tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
    }
    if cfg.is_encdec:
        out["enc_feats"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if n_img:
        out["img_feats"] = jax.ShapeDtypeStruct((b, n_img, 1024), jnp.bfloat16)
    return out


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    n_img = cfg.num_image_tokens if cfg.family == "vlm" else 0
    out = {"tokens": jax.ShapeDtypeStruct((b, s - n_img), jnp.int32)}
    if cfg.is_encdec:
        out["enc_feats"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if n_img:
        out["img_feats"] = jax.ShapeDtypeStruct((b, n_img, 1024), jnp.bfloat16)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
