import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  1. materialize() produces the adaptive Plan (the paper's technique);
  2. the step function is lowered with the Plan's shardings and compiled;
  3. memory_analysis() proves per-chip fit -- if it exceeds the HBM budget
     the materializer ladder escalates and we recompile (the paper's
     reactive auto-scaling / runtime recompilation path);
  4. cost_analysis() + HLO collective parsing feed §Roofline;
  5. XLA counts scan bodies once, so the roofline FLOPs/bytes come from a
     two-point extrapolation: unrolled probes at num_blocks=1 and 2 give
     the exact per-block cost, then total = F1 + (NB-1)*(F2-F1).

Artifacts: artifacts/dryrun/{arch}__{shape}__{mesh}.json (resumable sweep).
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, list_archs, shape_applicable
from repro.core.history import HistoryStore
from repro.core.materializer import (MESHES, GB, Plan, escalate, materialize)
from repro.launch.input_specs import input_specs
from repro.launch.mesh import make_mesh_from_spec
from repro.models.model import Model
from repro.models.transformer import ImplConfig
from repro.sharding import planner
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step
from repro.serving.serve_step import make_decode_step, make_prefill_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind op count and output bytes from optimized HLO."""
    stats: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (\([^)]*\)|\S+) ([\w\-]+)\(", s)
        if not m:
            continue
        opname = m.group(2)
        # normalize variants like all-reduce-start, all-gather-done
        base = None
        for k in COLLECTIVES:
            if opname == k or opname.startswith(k + "-"):
                base = k
                break
        if base is None:
            continue
        if opname.endswith("-done"):
            continue  # avoid double counting start/done pairs
        stats[base]["count"] += 1
        stats[base]["bytes"] += _shape_bytes(m.group(1))
    return stats


def _merge_costs(c1: Dict, c2: Dict, nb: int) -> Dict[str, float]:
    """Two-point extrapolation: total = F1 + (nb - 1) * max(F2 - F1, 0).

    The per-block delta is clamped at zero: XLA occasionally CSEs a
    replicated collective at nb=2 that exists at nb=1, which would
    otherwise extrapolate to nonsense negative totals."""
    out = {}
    keys = set(c1) | set(c2)
    for k in keys:
        a, b = float(c1.get(k, 0.0)), float(c2.get(k, 0.0))
        out[k] = a + (nb - 1) * max(b - a, 0.0)
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _model_impl(plan: Plan, unroll: bool, nb_override: Optional[int],
                mesh=None, *, is_decode: bool = False) -> ImplConfig:
    shard_ctx = None
    if mesh is not None and is_decode and (plan.kv_shard_seq or plan.seq_axes):
        seq_axes = plan.seq_axes or ("model",)
        shard_ctx = (mesh, tuple(seq_axes), tuple(plan.batch_axes))
    ep_ctx = None
    if mesh is not None and plan.ep:
        ep_ctx = (mesh, "model", tuple(plan.batch_axes))
    return ImplConfig(attn_impl=plan.attn_impl,
                      remat=plan.remat if plan.shape == "train_4k" else "none",
                      scan_blocks=not unroll, unroll_blocks=unroll,
                      num_blocks_override=nb_override,
                      decode_shard_ctx=shard_ctx,
                      ep_shard_ctx=ep_ctx,
                      loss_chunk=plan.loss_chunk,
                      moe_dispatch=plan.moe_dispatch,
                      scan_chunk=plan.scan_chunk)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, plan: Plan, mesh,
               *, unroll: bool = False, nb_override: Optional[int] = None,
               donate: bool = True):
    """Build + lower the step for one cell under a plan.  Returns Lowered."""
    impl = _model_impl(plan, unroll, nb_override, mesh,
                       is_decode=shape.is_decode)
    model = Model(cfg, impl)
    specs = model.param_specs()
    pstructs = model.param_structs()
    p_sharding = planner.to_named(
        planner.param_specs_tree(plan, cfg, specs), mesh)
    ins = input_specs(cfg, shape)

    if shape.kind == "train":
        ostructs = opt.opt_state_structs(pstructs)
        o_sharding = {
            "m": planner.to_named(
                planner.opt_state_specs_tree(plan, cfg, specs), mesh),
            "v": planner.to_named(
                planner.opt_state_specs_tree(plan, cfg, specs), mesh),
            "master": planner.to_named(
                planner.opt_state_specs_tree(plan, cfg, specs), mesh),
            "count": NamedSharding(mesh, P()),
        }
        b_sharding = {
            k: NamedSharding(mesh, planner.batch_spec(plan, len(v.shape) - 1))
            for k, v in ins.items()}
        step = make_train_step(model, plan)
        jf = jax.jit(step,
                     in_shardings=(p_sharding, o_sharding, b_sharding),
                     out_shardings=(p_sharding, o_sharding, None),
                     donate_argnums=(0, 1) if donate else ())
        with mesh:
            return jf.lower(pstructs, ostructs, ins), model

    if shape.kind == "prefill":
        cache_structs = model.cache_specs(shape.global_batch, shape.seq_len)
        c_sharding = planner.to_named(
            planner.cache_specs_tree(plan, cfg, cache_structs), mesh)
        b_sharding = {
            k: NamedSharding(mesh, planner.batch_spec(plan, len(v.shape) - 1))
            for k, v in ins.items()}
        step = make_prefill_step(model, shape.seq_len)
        jf = jax.jit(step, in_shardings=(p_sharding, b_sharding),
                     out_shardings=(None, c_sharding))
        with mesh:
            return jf.lower(pstructs, ins), model

    # decode
    cache_structs = model.cache_specs(shape.global_batch, shape.seq_len)
    c_sharding = planner.to_named(
        planner.cache_specs_tree(plan, cfg, cache_structs), mesh)
    tok_sharding = NamedSharding(mesh, planner.batch_spec(plan, 1))
    pos_sharding = NamedSharding(mesh, P())
    step = make_decode_step(Model(cfg, impl))

    def decode(params, tokens, cache, pos):
        return step(params, tokens, cache, pos)

    jf = jax.jit(decode,
                 in_shardings=(p_sharding, tok_sharding, c_sharding,
                               pos_sharding),
                 out_shardings=(tok_sharding, None, c_sharding),
                 donate_argnums=(2,) if donate else ())
    with mesh:
        return jf.lower(pstructs, ins["tokens"], cache_structs, ins["pos"]), \
            Model(cfg, impl)


def cost_dict(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() across jax versions: older releases return
    a one-element list of dicts, newer ones the dict itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def memory_footprint(compiled) -> Dict[str, int]:
    """Per-device footprint.  ``peak_tpu_adjusted`` halves the temp term:
    XLA:CPU has no native bf16, so it materializes fp32 shadow copies of
    every bf16 tensor feeding a dot (verified in buffer-assignment dumps:
    the dominant temps are f32[...] shadows of bf16 weights/caches, exactly
    2x).  On the TPU target those conversions do not exist; halving the
    CPU temp is the documented, uniformly-applied correction."""
    ma = compiled.memory_analysis()
    state = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes)
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        "peak_bytes": state + int(ma.temp_size_in_bytes),
        "peak_tpu_adjusted": state + int(ma.temp_size_in_bytes) // 2,
    }


# ---------------------------------------------------------------------------
# Full cell run: compile + feedback + cost probes + roofline terms
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             history: Optional[HistoryStore] = None,
             overrides: Optional[Dict] = None,
             max_escalations: int = 6,
             cost_probes: bool = True,
             keep_hlo: bool = False) -> Dict[str, Any]:
    # the cell is an Application invocation class: resolve config/shape and
    # the proactive resource profile through the runtime's description
    from repro.runtime import Application

    shape = SHAPES[shape_name]
    app = (Application.train(arch, shape=shape) if shape.kind == "train"
           else Application.serve(arch, shape=shape))
    cfg = app.config
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    graph = app.resource_graph()
    mesh_spec = MESHES[mesh_name]
    mesh = make_mesh_from_spec(mesh_spec)
    plan = materialize(cfg, shape, mesh_spec, history=history,
                       overrides=overrides)
    budget = int(mesh_spec.hbm_per_device * 0.92)

    t0 = time.time()
    result: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                              "mesh": mesh_name}
    lowered = compiled = None
    for attempt in range(max_escalations + 1):
        lowered, _ = lower_cell(cfg, shape, plan, mesh)
        compiled = lowered.compile()
        mem = memory_footprint(compiled)
        if mem["peak_tpu_adjusted"] <= budget:
            break
        nxt = escalate(plan, cfg, shape, mem["peak_tpu_adjusted"])
        if nxt is None:
            plan.log("escalation exhausted; reporting over-budget compile")
            break
        plan = nxt
        jax.clear_caches()
    assert compiled is not None

    mem = memory_footprint(compiled)
    cost = cost_dict(compiled)
    hlo = compiled.as_text()
    colls = collective_stats(hlo)
    result.update({
        "status": "ok",
        "plan": plan.describe(),
        "resource_graph": {"compute": len(graph.compute),
                           "data": len(graph.data),
                           "estimated_demand_bytes": app.estimate_demand()},
        "memory": mem,
        "fits": mem["peak_tpu_adjusted"] <= budget,
        "hbm_budget": budget,
        "cost_scanned": {k: float(v) for k, v in cost.items()
                         if isinstance(v, (int, float))},
        "collectives_scanned": colls,
        "lower_compile_s": round(time.time() - t0, 2),
        "hlo_bytes": len(hlo),
    })
    if keep_hlo:
        result["hlo_head"] = hlo[:20000]

    # ---- two-point cost extrapolation (exact per-block costs) ------------
    if cost_probes:
        try:
            # probes lower one full-batch step without the microbatch
            # loop: total FLOPs are identical (mb x per-microbatch), and
            # nothing is executed so memory is irrelevant.
            probe_shape = shape
            probe_plan = dataclasses.replace(plan, microbatch=1)
            probe_plan.notes = []
            costs, coll_list = [], []
            for nb in (1, 2):
                l, _ = lower_cell(cfg, probe_shape, probe_plan, mesh,
                                  unroll=True, nb_override=nb, donate=False)
                c = l.compile()
                costs.append({k: float(v) for k, v in cost_dict(c).items()
                              if isinstance(v, (int, float))})
                coll_list.append(collective_stats(c.as_text()))
                del l, c
                jax.clear_caches()
            nb_total = cfg.num_blocks
            extr = _merge_costs(costs[0], costs[1], nb_total)
            coll_extr = {
                k: _merge_costs(coll_list[0][k], coll_list[1][k], nb_total)
                for k in COLLECTIVES}
            result["cost_extrapolated"] = extr
            result["collectives_extrapolated"] = coll_extr
            result["cost_probe_points"] = costs
        except Exception as e:  # pragma: no cover - probe robustness
            result["cost_probe_error"] = f"{type(e).__name__}: {e}"

    # ---- roofline terms ---------------------------------------------------
    result["roofline"] = roofline_terms(result, cfg, shape, mesh_spec)

    if history is not None:
        history.observe(arch, f"{shape_name}/{mesh_name}", "bytes_per_device",
                        mem["peak_bytes"])
        history.observe(arch, f"{shape_name}/{mesh_name}", "hlo_flops",
                        result["roofline"]["hlo_flops_per_device"])
        history.save()
    jax.clear_caches()
    return result


def roofline_terms(result: Dict, cfg: ModelConfig, shape: ShapeConfig,
                   mesh_spec) -> Dict[str, Any]:
    from repro.core import profiles as prof
    cost = result.get("cost_extrapolated") or result.get("cost_scanned", {})
    colls = (result.get("collectives_extrapolated")
             or result.get("collectives_scanned", {}))
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_bytes_dev = sum(d.get("bytes", 0.0) for d in colls.values())
    n_dev = mesh_spec.num_devices
    compute_s = flops_dev / mesh_spec.peak_flops
    memory_s = bytes_dev / mesh_spec.hbm_bw
    collective_s = coll_bytes_dev / mesh_spec.ici_bw
    model_flops = prof.step_model_flops(cfg, shape)
    hlo_flops_global = flops_dev * n_dev
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_bytes_dev,
        "compute_term_s": compute_s,
        "memory_term_s": memory_s,
        "collective_term_s": collective_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "step_time_bound_s": max(compute_s, memory_s, collective_s),
        "mfu_upper_bound": (model_flops
                            / (max(compute_s, memory_s, collective_s)
                               * n_dev * mesh_spec.peak_flops)
                            if max(compute_s, memory_s, collective_s) > 0
                            else 0.0),
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None,
                    choices=[None, "single_pod", "multi_pod"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", default=None,
                    help="JSON dict of Plan overrides (perf experiments)")
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(ARTIFACT_DIR)
    os.makedirs(out_dir, exist_ok=True)
    history = HistoryStore(os.path.join(os.path.dirname(out_dir), "history"))

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single_pod", "multi_pod"]
    overrides = json.loads(args.override) if args.override else None

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                tag = f"{arch}__{shape_name}__{mesh_name}"
                path = os.path.join(out_dir, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {tag}")
                    continue
                print(f"[run] {tag}", flush=True)
                try:
                    res = run_cell(arch, shape_name, mesh_name,
                                   history=history, overrides=overrides,
                                   cost_probes=not args.no_probes)
                except Exception as e:
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1, default=str)
                st = res.get("status")
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "error"
                if st == "ok":
                    r = res["roofline"]
                    print(f"  fits={res['fits']} "
                          f"peak={res['memory']['peak_tpu_adjusted']/GB:.2f}GiB(adj) "
                          f"dom={r['dominant']} "
                          f"mfu_ub={r['mfu_upper_bound']:.3f} "
                          f"t={res['lower_compile_s']}s", flush=True)
                elif st == "error":
                    print(f"  ERROR {res['error']}", flush=True)
                else:
                    print(f"  skipped: {res['reason']}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
