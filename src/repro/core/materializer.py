"""Adaptive materialization: resource graph -> physical execution plan.

This is the paper's core mechanism mapped to TPU pods.  The serverless
original adapts, per invocation, (a) which components co-locate in one
environment vs. get placed remotely, (b) component sizes from profiled
history, and (c) local-memory vs. remote-memory compilation versions.  The
TPU-native translation:

  server            -> chip          (fast local HBM)
  rack              -> pod           (fast ICI between chips)
  cross-rack        -> cross-pod     (slower DCN/pod links)
  co-located data   -> replicated weights / unsharded activations
  remote data       -> sharded weights (TP/FSDP): every access becomes a
                       collective, exactly the compiled "remote version"
  user-level swap   -> remat / microbatching / host offload
  component sizing  -> per-invocation remat depth, microbatch, KV layout

The *locality ladder* below is the paper's greedy placement policy
(§5.1.1): try the most-local materialization first, escalate to
progressively more "remote" (sharded / recomputed / offloaded) placements
only when the proactive per-chip memory estimate (profiles + history)
exceeds the HBM budget.  After lowering, the measured
``compiled.memory_analysis()`` feeds back (reactive auto-scaling, §5.1.2):
if the compiled footprint exceeds budget, the ladder escalates and
recompiles -- the "runtime re-compilation" path of the paper, cached by the
compile cache.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import profiles as prof
from repro.core.history import HistoryStore

GB = 1 << 30


@dataclass(frozen=True)
class MeshSpec:
    """Production mesh description (decoupled from jax device state)."""
    name: str
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    hbm_per_device: int = 16 * GB          # TPU v5e
    peak_flops: float = 197e12             # bf16 / chip
    hbm_bw: float = 819e9                  # bytes/s
    ici_bw: float = 50e9                   # bytes/s/link

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        return self.shape[self.axes.index(name)] if name in self.axes else 1

    @property
    def batch_capable_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a != "model")


SINGLE_POD = MeshSpec("single_pod", (16, 16), ("data", "model"))
MULTI_POD = MeshSpec("multi_pod", (2, 16, 16), ("pod", "data", "model"))

MESHES = {m.name: m for m in (SINGLE_POD, MULTI_POD)}


@dataclass
class Plan:
    """Physical materialization of one invocation class."""
    arch: str
    shape: str
    mesh: MeshSpec
    batch_axes: Tuple[str, ...] = ()
    seq_axes: Tuple[str, ...] = ()          # sequence / KV-seq sharding
    tp: bool = True                         # model axis does tensor parallel
    ep: bool = False                        # experts over model axis
    fsdp: bool = False                      # params sharded over data
    zero: bool = True                       # optimizer state sharded
    remat: str = "none"
    microbatch: int = 1
    attn_impl: str = "naive"
    kv_shard_heads: bool = False
    kv_shard_seq: bool = False
    offload_optimizer: bool = False
    grad_compression: Optional[str] = None  # e.g. "int8" on the pod axis
    loss_chunk: int = 0                     # chunked-CE streaming (0 = off)
    moe_dispatch: str = "psum"              # psum | a2a
    scan_chunk: int = 128                   # rwkv/ssd chunk length
    # FSDP dim choice: False = prefer non-contraction dims (H1; best terms);
    # True = legacy largest-dim (lower residency on some stacks: gemma3)
    fsdp_contracting: bool = False
    est_bytes_per_device: int = 0
    notes: List[str] = field(default_factory=list)

    def log(self, msg: str):
        self.notes.append(msg)

    @property
    def dp_degree(self) -> int:
        d = 1
        for a in self.batch_axes:
            d *= self.mesh.axis_size(a)
        return d

    def describe(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh.name,
            "batch_axes": self.batch_axes, "seq_axes": self.seq_axes,
            "tp": self.tp, "ep": self.ep, "fsdp": self.fsdp,
            "zero": self.zero, "remat": self.remat,
            "microbatch": self.microbatch, "attn_impl": self.attn_impl,
            "kv_shard_heads": self.kv_shard_heads,
            "kv_shard_seq": self.kv_shard_seq,
            "offload_optimizer": self.offload_optimizer,
            "grad_compression": self.grad_compression,
            "loss_chunk": self.loss_chunk,
            "moe_dispatch": self.moe_dispatch,
            "scan_chunk": self.scan_chunk,
            "fsdp_contracting": self.fsdp_contracting,
            "est_bytes_per_device": self.est_bytes_per_device,
            "notes": self.notes,
        }


# ---------------------------------------------------------------------------
# Proactive per-device byte estimation under a candidate plan
# ---------------------------------------------------------------------------

def estimate_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                              plan: Plan) -> int:
    mesh = plan.mesh
    tp_deg = mesh.axis_size("model") if plan.tp else 1
    dp_deg = max(plan.dp_degree, 1)
    data_deg = mesh.axis_size("data")

    pbytes = prof.param_bytes(cfg)
    p_dev = pbytes / tp_deg
    if plan.fsdp:
        p_dev /= data_deg

    if shape.kind == "train":
        obytes = prof.optimizer_bytes(cfg) / tp_deg
        if plan.zero or plan.fsdp:
            obytes /= data_deg
        if plan.offload_optimizer:
            obytes = 0
        grads = pbytes / tp_deg / (data_deg if plan.fsdp else 1)
        act = prof.activation_bytes_train(
            cfg, shape, plan.remat, plan.microbatch, plan.attn_impl)
        act_dev = act / dp_deg / tp_deg  # logits/attn shard over tp as well
        return int(p_dev + obytes + grads + act_dev)

    kv = prof.kv_cache_bytes(cfg, shape)
    kv_deg = dp_deg
    if plan.kv_shard_heads or plan.kv_shard_seq:
        kv_deg *= mesh.axis_size("model")
    if plan.seq_axes:
        d = 1
        for a in plan.seq_axes:
            d *= mesh.axis_size(a)
        kv_deg = max(kv_deg, d * dp_deg)
    act = shape.global_batch * max(shape.seq_len if shape.kind == "prefill"
                                   else 1, 1) * cfg.d_model * prof.BF16 * 8
    return int(p_dev + kv / max(kv_deg, 1) + act / max(dp_deg * tp_deg, 1))


# ---------------------------------------------------------------------------
# The locality ladder
# ---------------------------------------------------------------------------

def _pick_batch_axes(shape: ShapeConfig, mesh: MeshSpec,
                     include_model: bool) -> Tuple[str, ...]:
    """Largest prefix of (batch-capable [+ model]) axes dividing the batch."""
    axes = list(mesh.batch_capable_axes)
    if include_model:
        axes.append("model")
    chosen: List[str] = []
    degree = 1
    b = shape.global_batch
    for a in axes:
        s = mesh.axis_size(a)
        if b % (degree * s) == 0:
            chosen.append(a)
            degree *= s
    return tuple(chosen)


def materialize(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec, *,
                history: Optional[HistoryStore] = None,
                overrides: Optional[Dict] = None) -> Plan:
    """Proactive materialization: profiles (+ history) -> Plan."""
    plan = Plan(cfg.name, shape.name, mesh)
    budget = int(mesh.hbm_per_device * 0.92)

    # ---- history refinement: prefer measured bytes when available --------
    if history is not None:
        measured = history.peak(cfg.name, f"{shape.name}/{mesh.name}",
                                "bytes_per_device", 0.0)
        if measured:
            plan.log(f"history: measured peak {measured/GB:.2f} GiB/device "
                     "available; proactive sizing will be cross-checked")

    # ---- rung 0: structural choices ---------------------------------------
    # "all-local": pure data parallelism (params replicated, zero TP
    # collectives inside a step) -- feasible only if the batch covers the
    # whole mesh and the replicated state fits.
    all_local_axes = _pick_batch_axes(shape, mesh, include_model=True)
    all_local_deg = 1
    for a in all_local_axes:
        all_local_deg *= mesh.axis_size(a)

    if shape.kind != "train":
        _materialize_inference(cfg, shape, mesh, plan, budget)
        return _apply_overrides(plan, overrides, cfg, shape, budget)

    if "model" in all_local_axes and all_local_deg == mesh.num_devices:
        cand = dataclasses.replace(
            plan, tp=False, batch_axes=all_local_axes, zero=True)
        cand.notes = list(plan.notes)
        est = estimate_bytes_per_device(cfg, shape, cand)
        if est <= budget:
            cand.log(f"rung0: all-local DP({all_local_deg}) fits "
                     f"({est/GB:.2f} GiB <= {budget/GB:.2f} GiB)")
            cand.est_bytes_per_device = est
            plan = cand
        else:
            plan.log(f"rung0: all-local DP estimate {est/GB:.2f} GiB "
                     "exceeds budget; falling back to TP")
            plan.batch_axes = _pick_batch_axes(shape, mesh, False)
    else:
        plan.batch_axes = _pick_batch_axes(shape, mesh, False)
        plan.log(f"rung0: batch axes {plan.batch_axes} "
                 f"(global_batch={shape.global_batch}); model axis -> TP")

    if plan.tp and cfg.moe is not None:
        plan.ep = True
        plan.moe_dispatch = "a2a"
        plan.log("rung0: MoE arch -> expert parallelism over model axis "
                 "(a2a token exchange; measured 2.5x MFU-UB vs psum combine, "
                 "see EXPERIMENTS §Perf)")

    # long sequences force memory-bounded attention regardless of rung
    if shape.seq_len >= 8192 and not cfg.is_attention_free:
        plan.attn_impl = "chunked"
        plan.log("rung0: seq>=8k -> chunked (flash) attention")

    # ---- rungs 1..n: escalate until the proactive estimate fits -----------
    if plan.tp:
        # microbatch must keep the per-microbatch batch divisible by DP
        max_mb = max(shape.global_batch // max(plan.dp_degree, 1), 1)

        def mb_rung(m):
            return (f"microbatch={m}",
                    lambda p: dataclasses.replace(p, microbatch=m))

        rungs = [
            ("zero", lambda p: dataclasses.replace(p, zero=True)),
            ("remat=dots", lambda p: dataclasses.replace(p, remat="dots")),
            ("remat=full", lambda p: dataclasses.replace(p, remat="full")),
            ("fsdp", lambda p: dataclasses.replace(p, fsdp=True)),
        ]
        rungs += [mb_rung(m) for m in (2, 4) if m <= max_mb]
        rungs.append(("attn=chunked", lambda p: dataclasses.replace(
            p, attn_impl="chunked")))
        rungs += [mb_rung(m) for m in (8, 16) if m <= max_mb]
        # host offload of optimizer state: TPU memory-kind path; the CPU
        # dry-run backend cannot lower it (annotate_device_placement is
        # unsupported under SPMD replication), so it is opt-in only.

        est = estimate_bytes_per_device(cfg, shape, plan)
        for name, fn in rungs:
            if est <= budget:
                break
            notes = plan.notes
            plan = fn(plan)
            plan.notes = notes
            est = estimate_bytes_per_device(cfg, shape, plan)
            plan.log(f"ladder: +{name} -> est {est/GB:.2f} GiB/device")
        plan.est_bytes_per_device = int(est)
        if est > budget:
            plan.log("ladder exhausted: estimate still over budget; "
                     "compile feedback will decide")

    # cross-pod gradient sync is the slow link: compress when pod axis exists
    if "pod" in mesh.axes and shape.kind == "train":
        plan.grad_compression = None  # opt-in via overrides (beyond-paper)

    return _apply_overrides(plan, overrides, cfg, shape, budget)


def _materialize_inference(cfg: ModelConfig, shape: ShapeConfig,
                           mesh: MeshSpec, plan: Plan, budget: int) -> None:
    plan.batch_axes = _pick_batch_axes(shape, mesh, include_model=False)
    plan.zero = False
    plan.remat = "none"
    tp_size = mesh.axis_size("model")
    if cfg.moe is not None:
        plan.ep = True
    if shape.kind == "prefill":
        plan.attn_impl = "chunked" if shape.seq_len >= 8192 else "naive"
    # KV placement: heads over model axis when divisible, else sequence
    if cfg.num_kv_heads % tp_size == 0:
        plan.kv_shard_heads = True
        plan.log(f"kv: heads({cfg.num_kv_heads}) shard over model({tp_size})")
    else:
        plan.kv_shard_seq = True
        plan.log(f"kv: heads({cfg.num_kv_heads}) !% model({tp_size}); "
                 "sequence-sharded KV (flash-decode combine)")
    # batch=1 long-context: spread the sequence over every idle axis
    if shape.global_batch < mesh.axis_size("data"):
        leftover = tuple(a for a in mesh.batch_capable_axes
                         if a not in plan.batch_axes)
        plan.seq_axes = leftover + (("model",) if plan.kv_shard_seq else ())
        plan.log(f"long-context: seq axes {plan.seq_axes}")
    # weight-gathered serving: if TP-sharded params alone crowd the HBM,
    # shard them over the data axis too (all-gather per layer in the scan)
    p_dev = prof.param_bytes(cfg) / tp_size
    if p_dev > 0.5 * budget:
        plan.fsdp = True
        plan.log(f"params {p_dev/GB:.1f} GiB/device at TP{tp_size}: "
                 "weight-gathered serving (shard over data axis)")
    plan.est_bytes_per_device = estimate_bytes_per_device(cfg, shape, plan)
    plan.log(f"inference est {plan.est_bytes_per_device/GB:.2f} GiB/device")


def _apply_overrides(plan: Plan, overrides: Optional[Dict],
                     cfg: ModelConfig, shape: ShapeConfig,
                     budget: int) -> Plan:
    if overrides:
        notes = plan.notes
        plan = dataclasses.replace(plan, **overrides)
        plan.notes = notes
        plan.log(f"overrides applied: {overrides}")
        plan.est_bytes_per_device = estimate_bytes_per_device(cfg, shape, plan)
    return plan


def escalate(plan: Plan, cfg: ModelConfig, shape: ShapeConfig,
             measured_bytes: int) -> Optional[Plan]:
    """Compile-feedback escalation (reactive auto-scaling, §5.1.2).

    Called when ``compiled.memory_analysis()`` exceeds the HBM budget even
    though the proactive estimate fit.  Returns the next plan up the ladder,
    or None if exhausted."""
    budget = int(plan.mesh.hbm_per_device * 0.92)
    order: List[Tuple[str, Dict]] = []
    if not plan.tp:
        order.append(("enable TP", {"tp": True}))
    if plan.remat == "none":
        order.append(("remat=dots", {"remat": "dots"}))
    elif plan.remat == "dots":
        order.append(("remat=full", {"remat": "full"}))
    if not plan.zero and shape.kind == "train":
        order.append(("zero", {"zero": True}))
    if not plan.fsdp:
        # for inference this is weight-gathered serving: params shard over
        # the data axis and are all-gathered per layer inside the scan
        order.append(("fsdp", {"fsdp": True}))
    if plan.attn_impl == "naive" and not cfg.is_attention_free:
        order.append(("attn=chunked", {"attn_impl": "chunked"}))
    max_mb = max(shape.global_batch // max(plan.dp_degree, 1), 1)
    if plan.microbatch * 2 <= max_mb and shape.kind == "train":
        order.append((f"microbatch={plan.microbatch*2}",
                      {"microbatch": plan.microbatch * 2}))
    if plan.fsdp and not plan.fsdp_contracting:
        # last resort: switch the FSDP layout family -- some stacks
        # (gemma3) have lower residency under the legacy contraction-dim
        # sharding even though its roofline terms are worse
        order.append(("fsdp_contracting", {"fsdp_contracting": True}))
    if not order:
        return None
    name, kw = order[0]
    notes = list(plan.notes)
    new = dataclasses.replace(plan, **kw)
    new.notes = notes
    new.log(f"compile-feedback: measured {measured_bytes/GB:.2f} GiB > "
            f"budget {budget/GB:.2f} GiB -> {name}")
    return new
