"""Proactive compilation cache: the paper's pre-warm / pre-launch analog.

Paper §5.2.1 pre-launches the next component's environment while the current
one runs and caches runtime compilations per component layout (§4.2: "once
the runtime compiles a version for one invocation, it is cached and reused
for future invocations with the same component layouts").

TPU adaptation: the expensive environment setup is XLA compilation.  The
cache keys on (arch, shape, mesh, plan-layout) -- the "component layout" --
and stores compiled executables in-process plus XLA's persistent compilation
cache on disk for cross-process reuse.  ``prewarm`` compiles the *next*
expected invocation class on a background thread while the current one
executes (hiding setup behind the critical path, Fig. 7/23)."""

from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.core.materializer import Plan


def plan_layout_key(arch: str, shape: str, mesh: str, plan: Plan) -> str:
    """The paper's 'component layout' identity."""
    d = plan.describe()
    d.pop("notes", None)
    d.pop("est_bytes_per_device", None)
    blob = json.dumps({"arch": arch, "shape": shape, "mesh": mesh, **d},
                      sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


@dataclass
class CacheEntry:
    key: str
    compiled: Any
    compile_time_s: float
    hits: int = 0
    created: float = field(default_factory=time.time)


class CompileCache:
    def __init__(self, persistent_dir: Optional[str] = None):
        self._entries: Dict[str, CacheEntry] = {}
        self._lock = threading.Lock()
        self._inflight: Dict[str, threading.Event] = {}
        self.stats = {"hits": 0, "misses": 0, "prewarmed": 0,
                      "prewarm_hits": 0}
        if persistent_dir:
            # XLA persistent cache: cross-process reuse of compilations
            import jax
            os.makedirs(persistent_dir, exist_ok=True)
            jax.config.update("jax_compilation_cache_dir", persistent_dir)
            jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)

    def get_or_compile(self, key: str, build: Callable[[], Any]) -> Any:
        """Blocking fetch; compiles on miss (single-flight per key)."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is not None:
                ent.hits += 1
                self.stats["hits"] += 1
                return ent.compiled
            ev = self._inflight.get(key)
            if ev is None:
                ev = threading.Event()
                self._inflight[key] = ev
                owner = True
            else:
                owner = False
        if not owner:
            ev.wait()
            with self._lock:
                ent = self._entries.get(key)
                if ent is not None:
                    self.stats["hits"] += 1
                    return ent.compiled
            # fall through: owner failed; compile ourselves
        t0 = time.time()
        compiled = build()
        with self._lock:
            self.stats["misses"] += 1
            self._entries[key] = CacheEntry(key, compiled, time.time() - t0)
            self._inflight.pop(key, None)
        ev.set()
        return compiled

    def prewarm(self, key: str, build: Callable[[], Any]) -> threading.Thread:
        """Compile ahead of time on a background thread (pre-launch)."""
        def work():
            try:
                self.get_or_compile(key, build)
                with self._lock:
                    self.stats["prewarmed"] += 1
            except Exception:
                pass
        t = threading.Thread(target=work, daemon=True)
        t.start()
        return t

    def contains(self, key: str) -> bool:
        with self._lock:
            return key in self._entries
