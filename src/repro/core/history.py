"""History store: decaying histograms of per-component resource usage.

The paper (§4.2, §5.2.3) stores "a histogram of all captured statistics with
decaying weights at each resource graph node" and re-adjusts sizing
parameters every K executions.  This module is that store: observations are
bucketed into a log-scaled histogram whose weights decay geometrically with
each new sample, persisted as JSON per (app, component, metric).
"""

from __future__ import annotations

import json
import math
import os
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


DEFAULT_DECAY = 0.98
NUM_BUCKETS = 64


@dataclass
class DecayedHistogram:
    """Log-bucketed histogram with exponential decay on weights."""
    lo: float = 1.0
    hi: float = float(1 << 48)
    decay: float = DEFAULT_DECAY
    weights: List[float] = field(default_factory=lambda: [0.0] * NUM_BUCKETS)
    count: int = 0
    last: float = 0.0

    def _bucket(self, v: float) -> int:
        v = min(max(v, self.lo), self.hi)
        frac = (math.log(v) - math.log(self.lo)) / (
            math.log(self.hi) - math.log(self.lo))
        return min(NUM_BUCKETS - 1, int(frac * NUM_BUCKETS))

    def _bucket_value(self, i: int) -> float:
        frac = (i + 0.5) / NUM_BUCKETS
        return math.exp(math.log(self.lo) + frac
                        * (math.log(self.hi) - math.log(self.lo)))

    def observe(self, v: float) -> None:
        self.weights = [w * self.decay for w in self.weights]
        self.weights[self._bucket(v)] += 1.0
        self.count += 1
        self.last = v

    def quantile(self, q: float) -> float:
        total = sum(self.weights)
        if total <= 0:
            return 0.0
        acc = 0.0
        for i, w in enumerate(self.weights):
            acc += w
            if acc >= q * total:
                return self._bucket_value(i)
        return self._bucket_value(NUM_BUCKETS - 1)

    def mean(self) -> float:
        total = sum(self.weights)
        if total <= 0:
            return 0.0
        return sum(w * self._bucket_value(i)
                   for i, w in enumerate(self.weights)) / total

    def peak(self) -> float:
        return self.quantile(1.0)

    def samples(self) -> List[Tuple[float, float]]:
        """(value, weight) pairs for the sizing LP."""
        return [(self._bucket_value(i), w)
                for i, w in enumerate(self.weights) if w > 0]

    def to_json(self) -> dict:
        return {"lo": self.lo, "hi": self.hi, "decay": self.decay,
                "weights": self.weights, "count": self.count,
                "last": self.last}

    @classmethod
    def from_json(cls, d: dict) -> "DecayedHistogram":
        return cls(lo=d["lo"], hi=d["hi"], decay=d["decay"],
                   weights=list(d["weights"]), count=int(d["count"]),
                   last=float(d.get("last", 0.0)))


class HistoryStore:
    """Per-(app, component, metric) decayed histograms with JSON persistence.

    Thread-safe: the runtime records observations from the training loop and
    the serving engine concurrently.
    """

    def __init__(self, root: Optional[str] = None):
        self.root = root
        self._hists: Dict[str, DecayedHistogram] = {}
        self._lock = threading.Lock()
        if root:
            os.makedirs(root, exist_ok=True)
            self._load()

    @staticmethod
    def _key(app: str, component: str, metric: str) -> str:
        return f"{app}//{component}//{metric}"

    def observe(self, app: str, component: str, metric: str,
                value: float) -> None:
        key = self._key(app, component, metric)
        with self._lock:
            if key not in self._hists:
                self._hists[key] = DecayedHistogram()
            self._hists[key].observe(float(value))

    def get(self, app: str, component: str, metric: str
            ) -> Optional[DecayedHistogram]:
        return self._hists.get(self._key(app, component, metric))

    def quantile(self, app: str, component: str, metric: str, q: float,
                 default: float = 0.0) -> float:
        h = self.get(app, component, metric)
        return h.quantile(q) if h and h.count else default

    def peak(self, app: str, component: str, metric: str,
             default: float = 0.0) -> float:
        return self.quantile(app, component, metric, 1.0, default)

    # -- persistence --------------------------------------------------------
    def _path(self) -> str:
        return os.path.join(self.root, "history.json")

    def save(self) -> None:
        if not self.root:
            return
        with self._lock:
            payload = {k: h.to_json() for k, h in self._hists.items()}
        tmp = self._path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self._path())

    def _load(self) -> None:
        path = self._path()
        if not os.path.exists(path):
            return
        try:
            with open(path) as f:
                payload = json.load(f)
            self._hists = {k: DecayedHistogram.from_json(v)
                           for k, v in payload.items()}
        except (json.JSONDecodeError, KeyError):
            self._hists = {}
