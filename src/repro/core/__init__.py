from repro.core.graph import ResourceGraph, build_resource_graph
from repro.core.history import HistoryStore, DecayedHistogram
from repro.core.materializer import (MeshSpec, Plan, materialize, escalate,
                                     SINGLE_POD, MULTI_POD, MESHES)
from repro.core.sizing import solve_init_step, SizingSolution
from repro.core.scheduler import GlobalScheduler, PodScheduler, PodState, Job
from repro.core.compile_cache import CompileCache, plan_layout_key
from repro.core import annotations

__all__ = ["ResourceGraph", "build_resource_graph", "HistoryStore",
           "DecayedHistogram", "MeshSpec", "Plan", "materialize", "escalate",
           "SINGLE_POD", "MULTI_POD", "MESHES", "solve_init_step",
           "SizingSolution", "GlobalScheduler", "PodScheduler", "PodState",
           "Job", "CompileCache", "plan_layout_key", "annotations"]
