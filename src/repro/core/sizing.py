"""History-based resource sizing: the paper's §9.3 optimization.

For each component, pick an *initial size* and an *incremental size* so
that (appendix 9.3):

    min_{step,init}  init + sum_h step * k_h * cost_factor
    s.t.             k_h * step + init >= h              for all h in History
                     sum_h max(init - h, 0) * t_h / sum_h h  <  Thres

where k_h = ceil((h - init) / step) is the number of runtime scale-ups
needed for historical usage h.  The paper solves this with an ortools MIP;
`init` and `step` are two scalars over a discrete candidate set, so we solve
it exactly by vectorized enumeration over the history support (numpy),
mimicking the MIP interface.  This drives KV-cache page-pool sizing, the
serving admission controller and activation-buffer pools.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SizingSolution:
    init: float
    step: float
    expected_cost: float
    expected_scaleups: float
    waste_ratio: float
    feasible: bool


def solve_init_step(history: Sequence[Tuple[float, float]], *,
                    cost_factor: float = 0.3,
                    waste_threshold: float = 0.25,
                    exec_times: Optional[Sequence[float]] = None,
                    quantum: float = 1.0,
                    scale_penalty: Optional[float] = None) -> SizingSolution:
    """Exact solve of the §9.3 program over the weighted history.

    history: (value, weight) pairs (e.g. DecayedHistogram.samples()).
    quantum: allocation granularity (e.g. page size in tokens, MB, ...).
    scale_penalty: latency cost charged PER scale-up event (k_h), realizing
    the paper's "avoid frequent small resource adjustments" (§5.2.3): the
    literal §9.3 objective charges k_h*step (the scaled amount), which is
    nearly step-invariant; the per-event term makes the step size matter.
    Defaults to 2x the quantum."""
    if not history:
        return SizingSolution(quantum, quantum, 0.0, 0.0, 0.0, True)
    vals = np.asarray([max(quantum, v) for v, _ in history], np.float64)
    wts = np.asarray([w for _, w in history], np.float64)
    wts = wts / wts.sum()
    tms = (np.asarray(list(exec_times), np.float64)
           if exec_times is not None else np.ones_like(vals))
    peak = float(vals.max())

    # candidate grids on the allocation quantum
    qs = np.unique(np.concatenate([
        np.ceil(vals / quantum) * quantum,
        np.ceil(np.quantile(vals, [0.25, 0.5, 0.75, 0.9]) / quantum) * quantum,
        [quantum]]))
    inits = qs
    steps = np.unique(np.concatenate([
        qs, np.ceil((peak - qs) / (4 * quantum)) * quantum + quantum]))
    steps = steps[steps >= quantum]

    I = inits[:, None, None]                    # (i, 1, 1)
    S = steps[None, :, None]                    # (1, s, 1)
    V = vals[None, None, :]                     # (1, 1, h)
    W = wts[None, None, :]
    T = tms[None, None, :]

    if scale_penalty is None:
        scale_penalty = 2.0 * quantum
    k = np.ceil(np.maximum(V - I, 0.0) / S)     # scale-ups per history point
    cost = I[..., 0] * 1.0 + (k * S * cost_factor * W).sum(-1) \
        + (k * scale_penalty * W).sum(-1)
    # waste: allocated-but-unused, time-weighted, relative to used
    waste = (np.maximum(I - V, 0.0) * T * W).sum(-1) / max(
        float((V * W).sum()), 1e-9)
    waste = np.broadcast_to(waste, cost.shape)
    feasible = waste < waste_threshold
    cost = np.where(feasible, cost, np.inf)

    i_idx, s_idx = np.unravel_index(np.argmin(cost), cost.shape)
    if not np.isfinite(cost[i_idx, s_idx]):
        # no feasible point: fall back to peak provisioning (paper's bound)
        return SizingSolution(peak, quantum, peak, 0.0, 0.0, False)
    init = float(inits[i_idx])
    step = float(steps[s_idx])
    ks = np.ceil(np.maximum(vals - init, 0.0) / step)
    return SizingSolution(
        init=init, step=step,
        expected_cost=float(cost[i_idx, s_idx]),
        expected_scaleups=float((ks * wts).sum()),
        waste_ratio=float(waste[i_idx, s_idx]),
        feasible=True)


def fixed_sizing(init: float, step: float) -> SizingSolution:
    """The paper's fixed-size baseline (256 MB / 64 MB in Fig. 22)."""
    return SizingSolution(init, step, init, 0.0, 0.0, True)


def peak_sizing(history: Sequence[Tuple[float, float]]) -> SizingSolution:
    """Peak-provisioning baseline: allocate the historical max up front."""
    peak = max((v for v, _ in history), default=1.0)
    return SizingSolution(peak, peak, peak, 0.0, 0.0, True)


def simulate_policy(history_values: Sequence[float], sol: SizingSolution,
                    scale_latency: float = 1.0, base_latency: float = 10.0
                    ) -> dict:
    """Replay a usage trace under a sizing policy.

    Returns utilization + normalized completion-time stats (the Fig. 22
    metrics: memory utilization and performance under fixed / peak /
    history-based sizing)."""
    used = np.asarray(history_values, np.float64)
    alloc = np.maximum(
        sol.init,
        sol.init + np.ceil(np.maximum(used - sol.init, 0) / max(sol.step, 1e-9))
        * sol.step)
    scaleups = np.ceil(np.maximum(used - sol.init, 0) / max(sol.step, 1e-9))
    time = base_latency + scaleups * scale_latency
    return {
        "mean_utilization": float((used / alloc).mean()),
        "mean_alloc": float(alloc.mean()),
        "mean_used": float(used.mean()),
        "mean_scaleups": float(scaleups.mean()),
        "mean_time": float(time.mean()),
        "p99_time": float(np.quantile(time, 0.99)),
    }
