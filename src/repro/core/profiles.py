"""Resource profiles: analytic FLOPs / bytes per component and per step.

This is the offline-profiler analog of the paper: every resource-graph node
carries a resource feature (CPU usage -> FLOPs; allocation size/lifetime ->
bytes) that the materializer uses for *proactive* placement and sizing
decisions before anything is compiled or executed.  After a dry-run compile,
measured HLO numbers are folded back through the HistoryStore (sample-based
profiling), refining these estimates for future invocations.
"""

from __future__ import annotations

import dataclasses


from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, ATTN_SHARED,
                                DEC_ATTN, ENC_ATTN, MAMBA2, MOE, RWKV6,
                                ModelConfig, ShapeConfig)

BF16 = 2
FP32 = 4


# ---------------------------------------------------------------------------
# Parameter counts
# ---------------------------------------------------------------------------

def model_param_count(cfg: ModelConfig) -> int:
    from repro.models.transformer import model_specs
    from repro.models.layers import param_count
    return param_count(model_specs(cfg))


def model_active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top-k routed + shared only)."""
    total = model_param_count(cfg)
    if cfg.moe is None:
        return total
    from repro.models.moe import padded_num_experts
    m = cfg.moe
    e_pad = padded_num_experts(m.num_experts)
    routed_per_layer = 3 * e_pad * cfg.d_model * m.d_expert
    n_moe_layers = sum(1 for k in cfg.pattern if k == MOE) * cfg.num_blocks
    active_per_layer = 3 * m.top_k * cfg.d_model * m.d_expert
    return total - n_moe_layers * (routed_per_layer - active_per_layer)


def param_bytes(cfg: ModelConfig, bytes_per_param: int = BF16) -> int:
    return model_param_count(cfg) * bytes_per_param


def optimizer_bytes(cfg: ModelConfig) -> int:
    """AdamW: fp32 m + v (+ fp32 master copy)."""
    n = model_param_count(cfg)
    return n * (FP32 + FP32 + FP32)


# ---------------------------------------------------------------------------
# Per-block analytic FLOPs (forward, per token)
# ---------------------------------------------------------------------------

def _attn_proj_flops(cfg: ModelConfig) -> int:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return 2 * d * (h * hd + 2 * kv * hd + h * hd)  # q,k,v,o


def _attn_score_flops(cfg: ModelConfig, kv_len: int) -> int:
    return 2 * 2 * cfg.num_heads * cfg.head_dim * kv_len  # scores + out


def _mlp_flops(cfg: ModelConfig, gated: bool = True) -> int:
    mult = 3 if gated else 2
    return 2 * mult * cfg.d_model * cfg.d_ff


def block_fwd_flops_per_token(cfg: ModelConfig, kind: str, seq_len: int,
                              causal: bool = True) -> int:
    """Forward FLOPs per token for one pattern-block entry."""
    kv_len = seq_len / 2 if causal else seq_len  # average causal footprint
    d = cfg.d_model
    if kind in (ATTN_GLOBAL, ENC_ATTN, ATTN_SHARED):
        return (_attn_proj_flops(cfg) + _attn_score_flops(cfg, int(kv_len))
                + _mlp_flops(cfg, gated=kind != ENC_ATTN))
    if kind == ATTN_LOCAL:
        w = min(cfg.sliding_window, seq_len)
        return (_attn_proj_flops(cfg) + _attn_score_flops(cfg, w)
                + _mlp_flops(cfg))
    if kind == DEC_ATTN:
        cross = _attn_score_flops(cfg, cfg.encoder_seq_len)
        return (2 * _attn_proj_flops(cfg) + _attn_score_flops(cfg, int(kv_len))
                + cross + _mlp_flops(cfg, gated=False))
    if kind == MOE:
        m = cfg.moe
        routed = 2 * 3 * m.top_k * d * m.d_expert
        shared = 2 * 3 * d * m.d_shared_expert if m.num_shared_experts else 0
        router = 2 * d * m.num_experts
        return (_attn_proj_flops(cfg) + _attn_score_flops(cfg, int(kv_len))
                + routed + shared + router)
    if kind == RWKV6:
        proj = 2 * 5 * d * d + 2 * d * d          # r,k,v,g,o + cr
        wkv = 2 * 2 * cfg.num_heads * cfg.head_dim * cfg.head_dim
        cmix = 2 * (d * cfg.d_ff * 2)
        lora = 2 * d * 64 * 2
        return proj + wkv + cmix + lora
    if kind == MAMBA2:
        from repro.models.mamba2 import mamba_dims
        d_inner, h, p_dim, n = mamba_dims(cfg)
        proj = 2 * d * (2 * d_inner + 2 * n + h) + 2 * d_inner * d
        ssd = 2 * 2 * h * p_dim * n               # state update + readout
        chunk = cfg.ssm.chunk_size
        intra = 2 * 2 * chunk * (n + p_dim) / 2   # intra-chunk attn-like
        return int(proj + ssd + intra * h / max(h, 1) * h)
    raise ValueError(kind)


def step_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """MODEL_FLOPS per assignment: 6*N*T (train) / 2*N*T (fwd), N active."""
    n = model_active_param_count(cfg)
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    return mult * n * tokens


def step_hlo_flops_estimate(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Analytic estimate of compiled FLOPs (incl. attention quadratics)."""
    if shape.is_decode:
        tokens = shape.global_batch
        per_tok = sum(block_fwd_flops_per_token(cfg, k, shape.seq_len,
                                                causal=False)
                      for k in cfg.pattern) * cfg.num_blocks
    else:
        tokens = shape.global_batch * shape.seq_len
        per_tok = sum(block_fwd_flops_per_token(cfg, k, shape.seq_len)
                      for k in cfg.pattern) * cfg.num_blocks
    head = 2 * cfg.d_model * cfg.vocab_size
    mult = 3 if shape.kind == "train" else 1
    return int(tokens * (per_tok * mult + head * (mult if shape.kind ==
                                                  "train" else 1)))


# ---------------------------------------------------------------------------
# Memory footprints (per step, global bytes)
# ---------------------------------------------------------------------------

def kv_cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Global KV-cache / recurrent-state bytes for decode shapes."""
    if not shape.is_decode and shape.kind != "prefill":
        return 0
    b, s = shape.global_batch, shape.seq_len
    total = 0
    for kind in cfg.pattern:
        if kind in (ATTN_GLOBAL, MOE, ATTN_SHARED, DEC_ATTN):
            total += 2 * b * s * cfg.kv_dim * BF16
            if kind == DEC_ATTN:
                total += 2 * b * cfg.encoder_seq_len * cfg.kv_dim * BF16
        elif kind == ATTN_LOCAL:
            w = min(cfg.sliding_window, s)
            total += 2 * b * w * cfg.kv_dim * BF16
        elif kind == RWKV6:
            total += b * cfg.num_heads * cfg.head_dim ** 2 * FP32
            total += 2 * b * cfg.d_model * BF16
        elif kind == MAMBA2:
            from repro.models.mamba2 import mamba_dims
            d_inner, h, p_dim, n = mamba_dims(cfg)
            total += b * h * p_dim * n * FP32
            total += b * (cfg.ssm.conv_width - 1) * (d_inner + 2 * n) * BF16
    return total * cfg.num_blocks


def activation_bytes_train(cfg: ModelConfig, shape: ShapeConfig,
                           remat: str = "full", microbatch: int = 1,
                           attn_impl: str = "naive") -> int:
    """Global activation residency during a train step (analytic)."""
    b = shape.global_batch // microbatch
    s = shape.seq_len
    t = b * s
    d = cfg.d_model
    n_layers = cfg.num_layers
    if remat == "full":
        # saved: per-block input (+ scan carries)
        per_layer = t * d * BF16
    elif remat == "dots":
        per_layer = t * d * BF16 * 6
    else:
        per_layer = t * d * BF16 * 14
    act = n_layers * per_layer
    # attention score tile residency (transient, bounded by impl)
    if attn_impl == "naive":
        act += b * cfg.num_heads * s * s * BF16
    else:
        act += b * cfg.num_heads * 1024 * s * BF16
    # logits + unembed fp32
    act += t * cfg.vocab_size * FP32 // max(1, 1)
    return act


@dataclasses.dataclass
class StepProfile:
    """One invocation class's proactive resource profile."""
    model_flops: int
    hlo_flops_est: int
    param_bytes: int
    optimizer_bytes: int
    kv_bytes: int
    activation_bytes: int

    @property
    def total_state_bytes(self) -> int:
        return (self.param_bytes + self.optimizer_bytes + self.kv_bytes
                + self.activation_bytes)


def step_profile(cfg: ModelConfig, shape: ShapeConfig, *,
                 remat: str = "full", microbatch: int = 1,
                 attn_impl: str = "naive") -> StepProfile:
    is_train = shape.kind == "train"
    return StepProfile(
        model_flops=step_model_flops(cfg, shape),
        hlo_flops_est=step_hlo_flops_estimate(cfg, shape),
        param_bytes=param_bytes(cfg),
        optimizer_bytes=optimizer_bytes(cfg) if is_train else 0,
        kv_bytes=kv_cache_bytes(cfg, shape),
        activation_bytes=(activation_bytes_train(cfg, shape, remat,
                                                 microbatch, attn_impl)
                          if not shape.is_decode else
                          shape.global_batch * cfg.d_model * BF16 * 4),
    )
