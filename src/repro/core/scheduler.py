"""Two-level scheduler: global (cross-pod) + pod-level (per-pod).

Paper §5.3.1: one global scheduler balances application requests across
racks; each rack-level scheduler places components on servers and keeps an
exact view of per-server free resources.  TPU adaptation: the global
scheduler balances *jobs* (training runs / serving replicas) across pods;
each pod scheduler places a job's resource-graph components onto chips via
the materializer and tracks HBM/chip occupancy.  The same objects drive
both real execution and the event-driven trace replay in
``repro.runtime.simulate`` (the paper's 50k invocations/s global, 20k
components/s rack claims).

Placement policy (§5.1.1): locality-greedy best-fit -- choose the pod with
the *smallest* sufficient free capacity, leaving larger pods free for
future bulky invocations; pre-mark (low-priority reserve) the remaining
profile-estimated demand of a running application.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.graph import ResourceGraph
from repro.core.history import HistoryStore
from repro.core.materializer import Plan
from repro.obs import trace as obs_trace

GB = 1 << 30


@dataclass
class Job:
    job_id: str
    app: str                       # arch name
    kind: str                      # train | serve
    demand_bytes: int              # profile-estimated footprint
    demand_chips: int
    graph: Optional[ResourceGraph] = None
    plan: Optional[Plan] = None
    pod: Optional[str] = None
    state: str = "pending"         # pending | running | done | failed
    peak_bytes: int = 0            # high-water demand (history record)


@dataclass
class PodState:
    name: str
    num_chips: int
    hbm_per_chip: int
    free_bytes: int = 0
    reserved_bytes: int = 0        # low-priority marks (paper §5.1.1)
    running: Dict[str, Job] = field(default_factory=dict)

    def __post_init__(self):
        if self.free_bytes == 0:
            self.free_bytes = self.num_chips * self.hbm_per_chip

    @property
    def available(self) -> int:
        return self.free_bytes

    @property
    def available_unreserved(self) -> int:
        return max(self.free_bytes - self.reserved_bytes, 0)


class PodScheduler:
    """Rack-level analog: places components of one job onto chips."""

    def __init__(self, pod: PodState, history: Optional[HistoryStore] = None):
        self.pod = pod
        self.history = history
        self.placements: Dict[str, Dict[str, str]] = {}

    def admit(self, job: Job) -> bool:
        if job.demand_bytes > self.pod.available:
            return False
        self.pod.free_bytes -= job.demand_bytes
        self.pod.running[job.job_id] = job
        job.pod = self.pod.name
        job.state = "running"
        job.peak_bytes = max(job.peak_bytes, job.demand_bytes)
        if job.graph is not None:
            self.placements[job.job_id] = self._place_components(job)
        return True

    def _place_components(self, job: Job) -> Dict[str, str]:
        """Locality-greedy per-component placement record.

        Components that fit together are 'merged' (one device group); data
        components whose accessors are all co-located are local, others are
        sharded ('remote')."""
        out = {}
        g = job.graph
        for name in g.topo_order():
            out[name] = "merged/local"
        for dname, d in g.data.items():
            accs = set(g.accessors(dname))
            out[dname] = ("local" if len(accs) <= 1 else
                          "shared/sharded")
        return out

    def scale_up(self, job_id: str, extra_bytes: int) -> bool:
        """Runtime component growth (paper §5.1.2 data-component scaling)."""
        job = self.pod.running.get(job_id)
        if job is None or extra_bytes > self.pod.available:
            return False
        self.pod.free_bytes -= extra_bytes
        job.demand_bytes += extra_bytes
        job.peak_bytes = max(job.peak_bytes, job.demand_bytes)
        return True

    def scale_down(self, job_id: str, release_bytes: int) -> int:
        """Shrink a running job, returning bytes actually freed."""
        job = self.pod.running.get(job_id)
        if job is None:
            return 0
        freed = min(release_bytes, job.demand_bytes)
        job.demand_bytes -= freed
        self.pod.free_bytes += freed
        return freed

    def release(self, job_id: str) -> None:
        job = self.pod.running.pop(job_id, None)
        if job is not None:
            self.pod.free_bytes += job.demand_bytes
            job.state = "done"
        self.placements.pop(job_id, None)


class GlobalScheduler:
    """Cluster-level: balance jobs across pods (best-fit smallest pod)."""

    def __init__(self, pods: List[PodState],
                 history: Optional[HistoryStore] = None):
        self.pods = {p.name: PodScheduler(p, history) for p in pods}
        self.history = history
        self.pending: List[Job] = []
        self.completed: List[Job] = []
        self.rejected: List[Job] = []
        # per-job low-priority reservations (pre-marked future demand);
        # released on finish so pods regain available_unreserved capacity
        self.reservations: Dict[str, Tuple[str, int]] = {}

    def submit(self, job: Job) -> Optional[str]:
        """Paper policy: smallest pod with sufficient free resources.

        Pre-marked reservations are low-priority (§5.1.1): admission first
        looks for a pod whose UNRESERVED capacity fits the job, and only
        when none exists takes space out of another job's reserve."""
        cands = [(ps.pod.available_unreserved, name)
                 for name, ps in self.pods.items()
                 if ps.pod.available_unreserved >= job.demand_bytes]
        if not cands:
            cands = [(ps.pod.available, name)
                     for name, ps in self.pods.items()
                     if ps.pod.available >= job.demand_bytes]
        if not cands:
            self.pending.append(job)
            t = obs_trace.TRACER
            if t is not None:
                t.instant("scheduler", "job_pending", job.job_id,
                          {"app": job.app,
                           "demand_bytes": job.demand_bytes})
            return None
        _, name = min(cands)
        ok = self.pods[name].admit(job)
        if not ok:  # raced; retry queue
            self.pending.append(job)
            return None
        t = obs_trace.TRACER
        if t is not None:
            t.instant("scheduler", "job_admit", job.job_id,
                      {"app": job.app, "pod": name,
                       "demand_bytes": job.demand_bytes})
        # pre-mark estimated future demand (low-priority reservation)
        if self.history is not None:
            est_peak = self.history.peak(job.app, "job", "bytes",
                                         job.demand_bytes)
            mark = max(int(est_peak) - job.demand_bytes, 0)
            if mark:
                self.pods[name].pod.reserved_bytes += mark
                self.reservations[job.job_id] = (name, mark)
        return name

    def scale_up(self, job: Job, extra_bytes: int) -> bool:
        """Grow a running job, consuming its pre-marked reservation first."""
        if job.pod is None or not self.pods[job.pod].scale_up(
                job.job_id, extra_bytes):
            return False
        res = self.reservations.get(job.job_id)
        if res is not None:
            name, mark = res
            consumed = min(mark, extra_bytes)
            self.pods[name].pod.reserved_bytes -= consumed
            if mark - consumed > 0:
                self.reservations[job.job_id] = (name, mark - consumed)
            else:
                del self.reservations[job.job_id]
        return True

    def scale_down(self, job: Job, release_bytes: int) -> int:
        if job.pod is None:
            return 0
        return self.pods[job.pod].scale_down(job.job_id, release_bytes)

    # -- idle parking (resource-centric reclamation) -------------------------
    def park(self, job: Job, keep_bytes: int = 0) -> int:
        """Release an idle job's bytes back to its pod, pre-marking them as
        the job's low-priority reservation (§5.1.1): other work may take the
        space, but while it stays free the parked job reacquires it on
        unpark without re-placement.  Freed capacity drains the pending
        queue.  Returns the bytes actually freed."""
        if job.pod is None:
            return 0
        freed = self.scale_down(job, max(job.demand_bytes - keep_bytes, 0))
        if freed:
            pod, mark = self.reservations.get(job.job_id, (job.pod, 0))
            self.pods[pod].pod.reserved_bytes += freed
            self.reservations[job.job_id] = (pod, mark + freed)
            t = obs_trace.TRACER
            if t is not None:
                t.instant("scheduler", "job_park", job.job_id,
                          {"app": job.app, "freed_bytes": freed})
            self._drain_pending()
        return freed

    def unpark(self, job: Job, reacquire_bytes: int) -> bool:
        """Reacquire a parked job's bytes (consumes the park reservation).
        False when co-tenants took the space in the meantime."""
        ok = self.scale_up(job, reacquire_bytes)
        t = obs_trace.TRACER
        if t is not None:
            t.instant("scheduler", "job_unpark", job.job_id,
                      {"app": job.app, "ok": ok,
                       "reacquire_bytes": reacquire_bytes})
        return ok

    def cancel(self, job: Job) -> bool:
        """Drop a still-pending job from the queue."""
        if job in self.pending:
            self.pending.remove(job)
            job.state = "failed"
            self.rejected.append(job)
            return True
        return False

    def _release_reservation(self, job: Job) -> None:
        res = self.reservations.pop(job.job_id, None)
        if res is not None:
            name, mark = res
            self.pods[name].pod.reserved_bytes -= mark

    def finish(self, job: Job) -> None:
        if job.pod:
            self.pods[job.pod].release(job.job_id)
        self._release_reservation(job)
        job.state = "done"
        self.completed.append(job)
        t = obs_trace.TRACER
        if t is not None:
            t.instant("scheduler", "job_finish", job.job_id,
                      {"app": job.app})
        if self.history is not None:
            # record the high-water working footprint, not the residual
            # demand: a parked (or scaled-down) job finishing with ~0
            # bytes would otherwise poison history-driven sizing for the
            # app's next submission
            self.history.observe(job.app, "job", "bytes",
                                 max(job.peak_bytes, job.demand_bytes))
        self._drain_pending()

    def _drain_pending(self) -> None:
        # drain pending queue: iterate a snapshot -- submit() re-appends
        # unplaceable jobs to self.pending, which must not be the list
        # being iterated (it would loop forever on the first failure)
        queued, self.pending = self.pending, []
        for j in queued:
            self.submit(j)
