"""Two-level scheduler: global (cross-pod) + pod-level (per-pod).

Paper §5.3.1: one global scheduler balances application requests across
racks; each rack-level scheduler places components on servers and keeps an
exact view of per-server free resources.  TPU adaptation: the global
scheduler balances *jobs* (training runs / serving replicas) across pods;
each pod scheduler places a job's resource-graph components onto chips via
the materializer and tracks HBM/chip occupancy.  The same objects drive the
event-driven simulator used for the scheduler-scalability benchmark (the
paper's 50k invocations/s global, 20k components/s rack claims).

Placement policy (§5.1.1): locality-greedy best-fit -- choose the pod with
the *smallest* sufficient free capacity, leaving larger pods free for
future bulky invocations; pre-mark (low-priority reserve) the remaining
profile-estimated demand of a running application.
"""

from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.graph import ResourceGraph
from repro.core.history import HistoryStore
from repro.core.materializer import MeshSpec, Plan, materialize

GB = 1 << 30


@dataclass
class Job:
    job_id: str
    app: str                       # arch name
    kind: str                      # train | serve
    demand_bytes: int              # profile-estimated footprint
    demand_chips: int
    graph: Optional[ResourceGraph] = None
    plan: Optional[Plan] = None
    pod: Optional[str] = None
    state: str = "pending"         # pending | running | done | failed


@dataclass
class PodState:
    name: str
    num_chips: int
    hbm_per_chip: int
    free_bytes: int = 0
    reserved_bytes: int = 0        # low-priority marks (paper §5.1.1)
    running: Dict[str, Job] = field(default_factory=dict)

    def __post_init__(self):
        if self.free_bytes == 0:
            self.free_bytes = self.num_chips * self.hbm_per_chip

    @property
    def available(self) -> int:
        return self.free_bytes

    @property
    def available_unreserved(self) -> int:
        return max(self.free_bytes - self.reserved_bytes, 0)


class PodScheduler:
    """Rack-level analog: places components of one job onto chips."""

    def __init__(self, pod: PodState, history: Optional[HistoryStore] = None):
        self.pod = pod
        self.history = history
        self.placements: Dict[str, Dict[str, str]] = {}

    def admit(self, job: Job) -> bool:
        if job.demand_bytes > self.pod.available:
            return False
        self.pod.free_bytes -= job.demand_bytes
        self.pod.running[job.job_id] = job
        job.pod = self.pod.name
        job.state = "running"
        if job.graph is not None:
            self.placements[job.job_id] = self._place_components(job)
        return True

    def _place_components(self, job: Job) -> Dict[str, str]:
        """Locality-greedy per-component placement record.

        Components that fit together are 'merged' (one device group); data
        components whose accessors are all co-located are local, others are
        sharded ('remote')."""
        out = {}
        g = job.graph
        for name in g.topo_order():
            out[name] = "merged/local"
        for dname, d in g.data.items():
            accs = set(g.accessors(dname))
            out[dname] = ("local" if len(accs) <= 1 else
                          "shared/sharded")
        return out

    def scale_up(self, job_id: str, extra_bytes: int) -> bool:
        """Runtime component growth (paper §5.1.2 data-component scaling)."""
        if extra_bytes > self.pod.available:
            return False
        self.pod.free_bytes -= extra_bytes
        self.pod.running[job_id].demand_bytes += extra_bytes
        return True

    def release(self, job_id: str) -> None:
        job = self.pod.running.pop(job_id, None)
        if job is not None:
            self.pod.free_bytes += job.demand_bytes
            job.state = "done"
        self.placements.pop(job_id, None)


class GlobalScheduler:
    """Cluster-level: balance jobs across pods (best-fit smallest pod)."""

    def __init__(self, pods: List[PodState],
                 history: Optional[HistoryStore] = None):
        self.pods = {p.name: PodScheduler(p, history) for p in pods}
        self.history = history
        self.pending: List[Job] = []
        self.completed: List[Job] = []
        self.rejected: List[Job] = []

    def submit(self, job: Job) -> Optional[str]:
        """Paper policy: smallest pod with sufficient free resources."""
        cands = [(ps.pod.available, name) for name, ps in self.pods.items()
                 if ps.pod.available >= job.demand_bytes]
        if not cands:
            self.pending.append(job)
            return None
        _, name = min(cands)
        ok = self.pods[name].admit(job)
        if not ok:  # raced; retry queue
            self.pending.append(job)
            return None
        # pre-mark estimated future demand (low-priority reservation)
        if self.history is not None:
            est_peak = self.history.peak(job.app, "job", "bytes",
                                         job.demand_bytes)
            self.pods[name].pod.reserved_bytes += max(
                int(est_peak) - job.demand_bytes, 0)
        return name

    def finish(self, job: Job) -> None:
        if job.pod:
            self.pods[job.pod].release(job.job_id)
        job.state = "done"
        self.completed.append(job)
        if self.history is not None:
            self.history.observe(job.app, "job", "bytes", job.demand_bytes)
        # drain pending queue
        still = []
        for j in self.pending:
            if self.submit(j) is None:
                still.append(j)
        self.pending = still


# ---------------------------------------------------------------------------
# Event-driven simulator (scheduler-scalability benchmark; paper claims
# 50k invocations/s global, 20k components/s per rack)
# ---------------------------------------------------------------------------

@dataclass(order=True)
class _Event:
    t: float
    seq: int
    kind: str = field(compare=False)
    job: Job = field(compare=False)


class ClusterSimulator:
    """Replays an arrival trace through the two-level scheduler."""

    def __init__(self, num_pods: int = 4, chips_per_pod: int = 256,
                 hbm_per_chip: int = 16 * GB,
                 history: Optional[HistoryStore] = None):
        pods = [PodState(f"pod{i}", chips_per_pod, hbm_per_chip)
                for i in range(num_pods)]
        self.sched = GlobalScheduler(pods, history)
        self._seq = itertools.count()

    def run(self, arrivals: List[Tuple[float, Job, float]]) -> Dict:
        """arrivals: (t_arrive, job, duration).  Returns throughput stats."""
        events: List[_Event] = []
        for t, job, dur in arrivals:
            heapq.heappush(events, _Event(t, next(self._seq), "arrive", job))
            job._duration = dur  # type: ignore[attr-defined]
        placed = finished = 0
        wall0 = time.perf_counter()
        while events:
            ev = heapq.heappop(events)
            if ev.kind == "arrive":
                pod = self.sched.submit(ev.job)
                if pod is not None:
                    placed += 1
                    heapq.heappush(events, _Event(
                        ev.t + ev.job._duration,  # type: ignore
                        next(self._seq), "finish", ev.job))
            else:
                self.sched.finish(ev.job)
                finished += 1
        wall = time.perf_counter() - wall0
        return {
            "placed": placed, "finished": finished,
            "wall_s": wall,
            "sched_ops_per_s": (placed + finished) / max(wall, 1e-9),
        }


def measure_scheduler_throughput(n_jobs: int = 50_000,
                                 num_pods: int = 8) -> Dict:
    """Micro-benchmark: pure scheduling decisions/second (no execution)."""
    import random
    rnd = random.Random(0)
    arrivals = []
    for i in range(n_jobs):
        demand = rnd.choice([1, 2, 4, 8, 16]) * GB
        job = Job(f"j{i}", f"app{i % 32}", "serve", demand, 1)
        arrivals.append((i * 1e-6, job, 1e-3))
    sim = ClusterSimulator(num_pods=num_pods)
    return sim.run(arrivals)
