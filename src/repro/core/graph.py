"""Resource graph: the paper's intermediate representation.

Nodes are *compute components* (code sites with distinctive FLOPs/parallelism
profiles) and *data components* (memory objects with distinctive
size/lifetime profiles).  Edges are ``triggers`` (compute -> compute) and
``accesses`` (compute -> data).

TPU adaptation: compute components are the model's pattern-block groups plus
embed/head/loss/optimizer; data components are parameter groups, optimizer
state, activations, KV caches and MoE dispatch buffers.  Weight sharing
(zamba2's shared attention) appears as one data component accessed by many
compute components -- exactly the paper's Figure 6 structure.

The graph carries proactive resource profiles (analytic, refined by history)
that the materializer uses for placement; the failure-recovery *cut*
semantics (§5.3.2) are defined over this graph as well.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.configs.base import (ATTN_SHARED, MOE, ModelConfig, ShapeConfig)
from repro.core import profiles as prof


@dataclass
class ComputeComponent:
    name: str
    kind: str                       # pattern kind | embed | head | optimizer
    flops: int                      # per invocation (global)
    parallelism: int                # max usable parallel units (tokens)
    count: int = 1                  # scanned repetitions (num_blocks)
    annotation: str = "@compute"


@dataclass
class DataComponent:
    name: str
    bytes: int                      # global bytes
    lifetime: str                   # step | persistent | transient
    input_dependent: bool = False   # size varies with invocation input
    annotation: str = "@data"


@dataclass
class Edge:
    src: str
    dst: str
    kind: str                       # triggers | accesses
    bytes: int = 0                  # data volume along the edge


@dataclass
class ResourceGraph:
    arch: str
    shape: str
    compute: Dict[str, ComputeComponent] = field(default_factory=dict)
    data: Dict[str, DataComponent] = field(default_factory=dict)
    edges: List[Edge] = field(default_factory=list)

    def add_compute(self, c: ComputeComponent):
        self.compute[c.name] = c

    def add_data(self, d: DataComponent):
        self.data[d.name] = d

    def connect(self, src: str, dst: str, kind: str, nbytes: int = 0):
        self.edges.append(Edge(src, dst, kind, nbytes))

    # -- queries used by the materializer / scheduler ----------------------
    def total_flops(self) -> int:
        return sum(c.flops * c.count for c in self.compute.values())

    def total_bytes(self, lifetimes=("step", "persistent")) -> int:
        return sum(d.bytes for d in self.data.values()
                   if d.lifetime in lifetimes)

    def accessors(self, data_name: str) -> List[str]:
        return [e.src for e in self.edges
                if e.kind == "accesses" and e.dst == data_name]

    def shared_data(self) -> List[str]:
        """Data components accessed by more than one compute component."""
        return [d for d in self.data if len(set(self.accessors(d))) > 1]

    def cut_boundaries(self) -> List[str]:
        """Compute components whose completion defines a recoverable cut:
        every edge crossing the boundary is persistently recordable."""
        # On the training substrate a cut is the optimizer update (a full
        # step); for serving it is each completed request batch.
        return [n for n, c in self.compute.items()
                if c.kind in ("optimizer", "head")]

    def topo_order(self) -> List[str]:
        """Trigger-edge topological order of compute components."""
        indeg = {n: 0 for n in self.compute}
        adj: Dict[str, List[str]] = {n: [] for n in self.compute}
        for e in self.edges:
            if e.kind == "triggers" and e.src in indeg and e.dst in indeg:
                adj[e.src].append(e.dst)
                indeg[e.dst] += 1
        order, q = [], [n for n, d in indeg.items() if d == 0]
        while q:
            n = q.pop(0)
            order.append(n)
            for m in adj[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    q.append(m)
        return order


def build_resource_graph(cfg: ModelConfig, shape: ShapeConfig
                         ) -> ResourceGraph:
    """Decompose one invocation class into the paper's IR."""
    g = ResourceGraph(cfg.name, shape.name)
    is_train = shape.kind == "train"
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 3 if is_train else 1

    # ---- embedding -------------------------------------------------------
    embed_bytes = cfg.vocab_size * cfg.d_model * prof.BF16
    g.add_data(DataComponent("w_embed", embed_bytes, "persistent"))
    g.add_compute(ComputeComponent(
        "embed", "embed", 2 * tokens * cfg.d_model * mult, tokens))
    g.connect("embed", "w_embed", "accesses", embed_bytes)

    # ---- pattern blocks ----------------------------------------------------
    from repro.models import transformer as T
    from repro.models import layers as L
    prev = "embed"
    for i, kind in enumerate(cfg.pattern):
        cname = f"block_p{i}_{kind}"
        flops = prof.block_fwd_flops_per_token(
            cfg, kind, shape.seq_len, causal=not shape.is_decode) * tokens * mult
        g.add_compute(ComputeComponent(cname, kind, flops, tokens,
                                       count=cfg.num_blocks))
        g.connect(prev, cname, "triggers", tokens * cfg.d_model * prof.BF16)
        if kind == ATTN_SHARED:
            if "w_shared_attn" not in g.data:
                sb = L.param_bytes(T.block_specs(cfg, kind))  # tiny ln only
                shared = T.shared_specs(cfg).get("shared_attn", {})
                sb += L.param_bytes(shared)
                g.add_data(DataComponent("w_shared_attn", sb, "persistent"))
            g.connect(cname, "w_shared_attn", "accesses")
        else:
            wb = L.param_bytes(T.block_specs(cfg, kind)) * cfg.num_blocks
            g.add_data(DataComponent(f"w_{cname}", wb, "persistent"))
            g.connect(cname, f"w_{cname}", "accesses", wb)
        if kind == MOE:
            # all-to-all dispatch buffer: transient, input-dependent
            cap_bytes = int(tokens * cfg.moe.top_k * cfg.moe.capacity_factor
                            * cfg.d_model * prof.BF16)
            g.add_data(DataComponent(f"dispatch_{i}", cap_bytes, "transient",
                                     input_dependent=True))
            g.connect(cname, f"dispatch_{i}", "accesses", cap_bytes)
        prev = cname

    # ---- head / loss -------------------------------------------------------
    head_flops = 2 * tokens * cfg.d_model * cfg.vocab_size * mult
    g.add_compute(ComputeComponent("head", "head", head_flops, tokens))
    g.connect(prev, "head", "triggers", tokens * cfg.d_model * prof.BF16)
    if not cfg.tie_embeddings:
        hb = cfg.d_model * cfg.vocab_size * prof.BF16
        g.add_data(DataComponent("w_head", hb, "persistent"))
        g.connect("head", "w_head", "accesses", hb)
    else:
        g.connect("head", "w_embed", "accesses", embed_bytes)

    # ---- step-scoped data components ---------------------------------------
    if is_train:
        g.add_data(DataComponent("activations",
                                 prof.activation_bytes_train(cfg, shape),
                                 "step", input_dependent=True))
        g.add_data(DataComponent("optimizer_state",
                                 prof.optimizer_bytes(cfg), "persistent"))
        g.add_compute(ComputeComponent(
            "optimizer", "optimizer", 10 * prof.model_param_count(cfg),
            prof.model_param_count(cfg)))
        g.connect("head", "optimizer", "triggers")
        g.connect("optimizer", "optimizer_state", "accesses",
                  prof.optimizer_bytes(cfg))
        for n, c in list(g.compute.items()):
            if n not in ("optimizer",):
                g.connect(n, "activations", "accesses")
    else:
        kvb = prof.kv_cache_bytes(cfg, shape)
        g.add_data(DataComponent("kv_cache", kvb, "persistent",
                                 input_dependent=True))
        for i, kind in enumerate(cfg.pattern):
            g.connect(f"block_p{i}_{kind}", "kv_cache", "accesses")
    return g
