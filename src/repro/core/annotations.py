"""User-facing annotations: the paper's @compute / @data / @app_limit.

In BulkX users annotate monolithic source programs; here users annotate
JAX model/program definitions.  Annotations register components with the
resource-graph builder so custom user programs (beyond the built-in
architectures) get the same adaptive treatment -- see examples/quickstart.py.
"""

from __future__ import annotations

import functools
import threading
from dataclasses import dataclass
from typing import Callable, List, Optional

_REGISTRY = threading.local()


def _components() -> List[dict]:
    if not hasattr(_REGISTRY, "items"):
        _REGISTRY.items = []
    return _REGISTRY.items


def reset_annotations() -> None:
    _REGISTRY.items = []


def collected_annotations() -> List[dict]:
    return list(_components())


@dataclass
class AppLimits:
    max_chips: Optional[int] = None
    max_hbm_bytes: Optional[int] = None


_APP_LIMITS = AppLimits()


def app_limit(*, max_chips: Optional[int] = None,
              max_hbm_bytes: Optional[int] = None):
    """Global spending cap (paper: @app_limit(max_cpu, max_mem))."""
    def deco(fn):
        global _APP_LIMITS
        _APP_LIMITS = AppLimits(max_chips, max_hbm_bytes)
        fn.__app_limits__ = _APP_LIMITS
        return fn
    return deco


def current_app_limits() -> AppLimits:
    return _APP_LIMITS


def compute(fn: Optional[Callable] = None, *, parallelism: str = "token",
            name: Optional[str] = None):
    """Mark a callable as a compute component (distinct FLOPs/parallelism).

    The wrapped function behaves identically; the call site is recorded so
    the resource-graph builder can create a node for it."""
    def deco(f):
        comp = {"kind": "compute", "name": name or f.__name__,
                "parallelism": parallelism, "fn": f.__qualname__}
        _components().append(comp)

        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            return f(*args, **kwargs)
        wrapper.__component__ = comp
        return wrapper
    if fn is not None:
        return deco(fn)
    return deco


def data(name: str, *, input_dependent: bool = False,
         lifetime: str = "step"):
    """Mark an array-producing callable as a data component."""
    def deco(f):
        comp = {"kind": "data", "name": name,
                "input_dependent": input_dependent, "lifetime": lifetime,
                "fn": f.__qualname__}
        _components().append(comp)

        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            return f(*args, **kwargs)
        wrapper.__component__ = comp
        return wrapper
    return deco
