"""Idle-application parking: resource-centric reclamation with warm
restart.

The paper's efficiency headline comes from the *platform* reclaiming
resources the application is not using.  For a serve app, "not using"
usually means idle-between-bursts -- yet an idle tenant still pins its KV
pool pages, its device KV arrays, and its scheduler bytes.  Parking
reclaims all three while keeping a warm restart cheap:

1. the engine **drains**: every running request's pages go back to the
   (shared) pool without completing the request;
2. the runner snapshots *the view's pages* of device KV to **host** in
   the checkpointer's array format (bf16 stored as uint16 + logical
   dtype, the exact on-disk leaf encoding of ``repro.checkpoint``); the
   pool-sized device arrays are dropped only when no co-tenant aliases
   them -- an aliased tenant's reclamation IS its physical pages
   returning to the shared free list for co-tenants to reuse;
3. the **scheduler** releases the job's bytes back to the pod,
   pre-marked as a low-priority reservation (§5.1.1) so unpark usually
   reacquires without re-placement -- and the freed capacity immediately
   drains the pending queue;
4. the app's ``PoolView`` is flagged parked, so it stops diluting
   co-tenants' fair shares.

Unparking is demand-driven -- the next ``submit_request`` (or
``run``) on a parked handle triggers it transparently -- and restores
token-identical decoding: drained requests re-acquire exactly their old
page *count* (fresh ids), the saved KV is scattered into the new pages,
and ``engine.running`` is rebuilt in drain order.  A request whose pages
cannot be re-granted (co-tenants consumed the pool meanwhile) falls back
to the at-least-once path: re-queued from scratch, still deterministic.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.analysis import zensan
from repro.obs import trace as obs_trace
from repro.serving.kv_cache import Request


@dataclass
class ParkedRequest:
    """One drained in-flight request: enough to re-grant and restore."""

    req: Request
    num_pages: int                  # growing-table (global-group) pages
    num_local_pages: int = 0        # sliding-window ring pages


@dataclass
class ParkedApp:
    """Everything a parked application needs to resume."""

    requests: List[ParkedRequest] = field(default_factory=list)
    runner_state: Optional[Dict] = None
    freed_bytes: int = 0
    freed_pages: int = 0
    parked_at: float = 0.0


def park_app(handle) -> Dict:
    """Park ``handle`` (a bound, running serve app).  Returns the
    reclamation receipt: freed pool pages, freed scheduler bytes, and the
    number of in-flight requests drained."""
    if handle.app.kind != "serve":
        raise ValueError(f"{handle.app.name}: only serve applications "
                         "park (a train app checkpoints and releases)")
    if handle.parked:
        raise RuntimeError(f"{handle.app.name}: already parked")
    eng = handle.engine
    if eng is None or handle.state != "running":
        raise RuntimeError(f"{handle.app.name}: park needs a bound, "
                           f"running application (state={handle.state})")
    migrated = None
    rset = handle.exec_state.get("replicas")
    if rset is not None and len(rset.replicas) > 1:
        # park IS scale-to-zero: fold the extra replicas into the primary
        # first (token-identical migration), then drain the primary below
        migrated = rset.scale_to(1)
    drained = eng.drain()
    runner = handle.runner
    runner_state = runner.park(drained) if runner is not None else None
    if runner is not None and "params" in handle.exec_state:
        # exec_state aliases the runner's params; a stale reference here
        # would keep the offloaded device tree alive
        handle.exec_state["params"] = None
    view = eng.pool
    if hasattr(view, "parked"):
        view.parked = True
    freed_pages = sum(len(g) + len(l) for _, (g, l) in drained)
    freed_bytes = handle.cluster.scheduler.park(handle.job)
    handle.exec_state["parked"] = ParkedApp(
        requests=[ParkedRequest(req, len(g), len(l))
                  for req, (g, l) in drained],
        runner_state=runner_state, freed_bytes=freed_bytes,
        freed_pages=freed_pages, parked_at=time.monotonic())
    receipt = {"freed_bytes": freed_bytes, "freed_pages": freed_pages,
               "drained_requests": len(drained),
               "kv_arrays_dropped": bool((runner_state or {}).get(
                   "arrays_dropped", runner_state is not None))}
    if migrated is not None:
        receipt["migrated_requests"] = migrated.get("migrated_requests", 0)
    s = zensan.SAN
    if s is not None:
        # quiescent point: every drained page must be back on the free
        # list, with one outstanding park receipt per drained request
        s.check(eng.pool)
    t = obs_trace.TRACER
    if t is not None:
        t.instant("autoscale", "park", handle.app.name, dict(receipt))
        for req, _ in drained:
            t.instant("request", "park", req.req_id,
                      {"app": handle.app.name})
    return receipt


def unpark_app(handle) -> Dict:
    """Resume a parked app: reacquire scheduler bytes, re-grant pages,
    scatter the saved KV back, rebuild ``engine.running`` in drain
    order.  Raises when the pod can no longer fit the app (its parked
    reservation was low-priority and other work took the space)."""
    parked: Optional[ParkedApp] = handle.exec_state.get("parked")
    if parked is None:
        return {}
    eng = handle.engine
    sched = handle.cluster.scheduler
    if parked.freed_bytes and not sched.unpark(handle.job,
                                               parked.freed_bytes):
        raise RuntimeError(
            f"{handle.app.name}: cannot unpark -- the pod no longer has "
            f"{parked.freed_bytes} free bytes (the parked reservation is "
            "low-priority; release other work or wait)")
    view = eng.pool
    if hasattr(view, "parked"):
        view.parked = False
    restored: List[ParkedRequest] = []
    requeued: List[ParkedRequest] = []
    runner = handle.runner
    reattach = getattr(runner, "prefix_reattach", None)
    for pr in parked.requests:
        # the park snapshot holds only PRIVATE pages; a request that was
        # decoding through shared prefix pages must re-pin the same token
        # chain first (the cache may have evicted it while parked --
        # then the snapshot is a torso without its head, so recompute)
        if reattach is not None and not reattach(pr.req):
            eng.pool.prefix_detach(pr.req)
            requeued.append(pr)
            continue
        ok = eng.pool.regrant(pr.req, pr.num_pages, pr.num_local_pages)
        while not ok:
            if not eng._reclaim():
                break
            ok = eng.pool.regrant(pr.req, pr.num_pages, pr.num_local_pages)
        if not ok:
            eng.pool.prefix_detach(pr.req)
        (restored if ok else requeued).append(pr)
    if runner is not None:
        runner.unpark(parked.runner_state, [pr.req for pr in restored])
        if "params" in handle.exec_state:
            handle.exec_state["params"] = runner.params
    eng.running.extend(pr.req for pr in restored)
    s = zensan.SAN
    for pr in requeued:          # at-least-once fallback: re-execute
        pr.req.generated = 0
        pr.req.state = "queued"
        eng.queue.appendleft(pr.req)
        eng.stats.preempted += 1
        if s is not None:
            # the requeued request re-enters from scratch: its park
            # receipt is resolved (nothing left to regrant), not stranded
            s.park_cancel(eng.pool, pr.req.req_id)
    if s is not None:
        s.unpark_done(eng.pool, getattr(eng.pool, "app", handle.app.name))
        s.check(eng.pool)
    del handle.exec_state["parked"]
    receipt = {"restored_requests": len(restored),
               "requeued_requests": len(requeued),
               "reacquired_bytes": parked.freed_bytes,
               "parked_s": time.monotonic() - parked.parked_at}
    t = obs_trace.TRACER
    if t is not None:
        t.instant("autoscale", "unpark", handle.app.name, dict(receipt))
        for pr in restored:
            t.instant("request", "unpark", pr.req.req_id,
                      {"app": handle.app.name, "restored": True})
        for pr in requeued:
            t.instant("request", "unpark", pr.req.req_id,
                      {"app": handle.app.name, "restored": False})
    return receipt
