"""Metrics windows: cumulative serving counters -> decayed per-window rates.

``AppHandle.serving_stats()`` surfaces monotonic lifetime counters (the
right primitive for accounting) but a scaling policy reasons about *rates*
-- TTFT of the last window, denials per second, whether the app saw any
traffic at all.  :class:`MetricsWindow` is the bridge: feed it one raw
stats snapshot per control-plane tick and it maintains

* ``window`` -- the raw deltas of the just-closed window (counters
  subtracted, gauges passed through), and
* ``rates`` -- EWMA-smoothed derived signals (``ttft_s``,
  ``decode_step_s``, ``denials_per_s``, ``tokens_per_s``,
  ``utilization``, ``queue_len``, ``num_running``), the paper's decayed
  history applied to the control loop, plus
* idleness tracking (``idle_s``) for the parking policy.

:func:`stats_delta` is the underlying windowed-semantics primitive, also
exposed through ``AppHandle.serving_stats(since=...)``.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.obs.metrics import hist_delta
from repro.serving.engine import EngineStats

#: monotonic counters at the top level of a serving_stats() dict
ENGINE_COUNTERS = EngineStats.COUNTERS

#: monotonic counters inside its ``pool`` sub-dict (PagePool.stats)
POOL_COUNTERS = ("grants", "grant_pages", "denials", "scaleups", "released",
                 "prefix_unpinned", "prefix_evictions")

#: monotonic counters inside the ``router`` sub-dict
#: (RequestRouter.stats); the rest are gauges (queue_len, num_replicas,
#: max_batch)
ROUTER_COUNTERS = ("submitted", "dispatched", "replicas_added",
                   "replicas_removed")


def stats_delta(cur: Dict, since: Dict) -> Dict:
    """Windowed view of a ``serving_stats()`` dict: counters accumulated
    since the ``since`` snapshot, gauges (utilization, queue depth, pool
    sizes) taken from ``cur``.  Window means (``mean_ttft_s``,
    ``mean_decode_step_s``) are recomputed from the deltas.

    Counter resets clamp to zero: a fresh engine re-registered under an
    old app name restarts every counter at 0, and a window must report
    "no progress observed" rather than a huge negative rate.  The
    optional ``hist`` sub-dict (repro.obs latency histograms) windows
    per-bucket with the same reset semantics (see
    :func:`repro.obs.metrics.hist_delta`)."""
    out = dict(cur)
    for k in ENGINE_COUNTERS:
        if k in out:
            out[k] = max(out[k] - since.get(k, 0), 0)
    out["mean_ttft_s"] = out.get("ttft_s_sum", 0.0) / max(
        out.get("ttft_count", 0), 1)
    out["mean_decode_step_s"] = out.get("decode_s_sum", 0.0) / max(
        out.get("decode_steps", 0), 1)
    if isinstance(cur.get("pool"), dict):
        spool = since.get("pool", {})
        if not isinstance(spool, dict):
            spool = {}
        out["pool"] = {k: max(v - spool.get(k, 0), 0)
                       if k in POOL_COUNTERS else v
                       for k, v in cur["pool"].items()}
    if isinstance(cur.get("shared_pool"), dict):
        sp = dict(cur["shared_pool"])
        ss = since.get("shared_pool", {})
        if not isinstance(ss, dict):
            ss = {}
        sp["cross_app_preemptions"] = max(
            sp.get("cross_app_preemptions", 0)
            - ss.get("cross_app_preemptions", 0), 0)
        for key in ("denials_by_app", "preemptions_by_app"):
            prev = ss.get(key, {})
            sp[key] = {a: max(n - prev.get(a, 0), 0)
                       for a, n in sp.get(key, {}).items()}
        out["shared_pool"] = sp
    if isinstance(cur.get("router"), dict):
        srt = since.get("router", {})
        if not isinstance(srt, dict):
            srt = {}
        out["router"] = {k: max(v - srt.get(k, 0), 0)
                         if k in ROUTER_COUNTERS else v
                         for k, v in cur["router"].items()}
    if isinstance(cur.get("replicas"), list):
        # per-replica breakdowns window by view name: replica indices
        # are reused across scale-down/up but each incarnation gets a
        # fresh pool view, so a missing/new view correctly deltas
        # against zero
        sreps = since.get("replicas")
        prev = ({e.get("view"): e for e in sreps if isinstance(e, dict)}
                if isinstance(sreps, list) else {})
        out["replicas"] = [
            {k: max(v - prev.get(e.get("view"), {}).get(k, 0), 0)
             if k in ENGINE_COUNTERS else v
             for k, v in e.items()}
            for e in cur["replicas"]]
    if isinstance(cur.get("hist"), dict):
        shist = since.get("hist", {})
        if not isinstance(shist, dict):
            shist = {}
        out["hist"] = {name: hist_delta(h, shist.get(name))
                       for name, h in cur["hist"].items()}
    return out


class MetricsWindow:
    """Per-application window state for the autoscale controller.

    ``observe(stats, now)`` closes one window: the first call only
    establishes the baseline; every later call computes deltas against
    the previous raw snapshot and folds the derived rates into an EWMA
    with weight ``alpha`` on the new window (the §4.2 decaying-histogram
    idea applied to control signals).
    """

    def __init__(self, alpha: float = 0.5):
        self.alpha = float(alpha)
        self.window: Dict = {}          # raw deltas of the last window
        self.rates: Dict[str, float] = {}   # EWMA-smoothed signals
        self.now: Optional[float] = None
        self.last_active_t: Optional[float] = None
        #: last observation that carried new arrivals (router submissions
        #: or engine admissions) -- the predictive unparker's anchor
        self.last_arrival_t: Optional[float] = None
        self._raw: Optional[Dict] = None
        self._t: Optional[float] = None

    def _smooth(self, key: str, value: Optional[float]) -> None:
        if value is None:
            return                       # no sample this window: hold
        prev = self.rates.get(key)
        self.rates[key] = (value if prev is None
                           else self.alpha * value
                           + (1.0 - self.alpha) * prev)

    def observe(self, stats: Dict, now: float) -> Dict:
        """Fold one raw ``serving_stats()`` snapshot taken at ``now``.
        Returns the smoothed ``rates`` dict."""
        now = float(now)
        self.now = now
        if self._raw is None:            # baseline window
            self._raw, self._t = stats, now
            self.last_active_t = now
            return self.rates
        dt = max(now - self._t, 1e-9)
        d = stats_delta(stats, self._raw)
        self.window = d
        self._raw, self._t = stats, now

        pool = d.get("pool", {}) if isinstance(d.get("pool"), dict) else {}
        self._smooth("ttft_s", d["mean_ttft_s"]
                     if d.get("ttft_count", 0) > 0 else None)
        self._smooth("decode_step_s", d["mean_decode_step_s"]
                     if d.get("decode_steps", 0) > 0 else None)
        self._smooth("denials_per_s", pool.get("denials", 0) / dt)
        self._smooth("tokens_per_s", d.get("tokens_generated", 0) / dt)
        self._smooth("admitted_per_s", d.get("admitted", 0) / dt)
        # arrival forecasting: front-end submissions when the app serves
        # through a router (admissions lag the router queue), else engine
        # admissions.  The smoothed inter-arrival gap is the predictive
        # unparker's periodicity estimate.
        router = d.get("router") if isinstance(d.get("router"), dict) else None
        arrivals = (router.get("submitted", 0) if router is not None
                    else d.get("admitted", 0))
        if arrivals > 0:
            if self.last_arrival_t is not None:
                self._smooth("arrival_gap_s",
                             (now - self.last_arrival_t) / arrivals)
            self.last_arrival_t = now
        self._smooth("submitted_per_s", arrivals / dt)
        # gauges: tracked un-smoothed (the current truth matters)
        for g in ("queue_len", "num_running", "pool_utilization",
                  "pool_used_pages", "pool_quota_pages"):
            if g in d:
                self.rates[g] = d[g]
        if router is not None:
            self.rates["num_replicas"] = router.get("num_replicas", 1)
            self.rates["max_batch"] = router.get("max_batch", 0)
            self.rates["router_queue_len"] = router.get("queue_len", 0)

        active = (arrivals > 0
                  or d.get("admitted", 0) > 0 or d.get("prefills", 0) > 0
                  or d.get("decode_steps", 0) > 0
                  or d.get("queue_len", 0) > 0
                  or d.get("num_running", 0) > 0)
        if active or self.last_active_t is None:
            self.last_active_t = now
        return self.rates

    @property
    def idle_s(self) -> float:
        """Seconds of observed inactivity (0 until two observations)."""
        if self.now is None or self.last_active_t is None:
            return 0.0
        return max(self.now - self.last_active_t, 0.0)
