"""Pluggable scaling policies: windowed signals in, decisions out.

Policies are deliberately dumb-and-pure: each looks at one application's
:class:`~repro.autoscale.metrics.MetricsWindow` and emits a
:class:`Decision`; the :class:`~repro.autoscale.controller.\
AutoscaleController` owns *when* decisions are applied (hysteresis,
cooldowns) and the handle owns *how* (``scale_up``/``scale_down``/
``park``).  Three built-ins:

* :class:`TargetTracking` -- the paper's feedback loop: track a TTFT /
  denial-rate target, growing by the §9.3 solved increment
  (``handle.sizing.step``) and shrinking when utilization stays low.
* :class:`IdleParker` -- request parking after a sustained idle window;
  unparking is demand-driven (``submit_request`` on a parked handle), so
  no policy ever needs to predict wake-ups.
* :class:`QuotaRebalancer` -- pod-level: resizes co-tenant ``PoolView``
  quotas on one shared pool in proportion to windowed demand, so the
  *provisioned* KV footprint tracks load instead of peak.

An application that opts into replica/batch scaling (a
:class:`~repro.runtime.options.ScalePolicy` on its ``ServeOptions``)
gets three more, all target-tracking on windowed signals:

* :class:`ReplicaScaler` -- replica count follows queue depth per
  replica (scale out) and decode occupancy (scale in; the removed
  replica's requests migrate token-identically).
* :class:`BatchScaler` -- the continuous-batch admission width follows
  decode occupancy, doubling/halving between ``batch_min`` and
  ``batch_max`` (the runners compile to ``batch_max`` up front, so no
  retrace).
* :class:`PredictiveUnparker` -- the one policy that acts on a *parked*
  app: unpark ``unpark_lead_s`` ahead of the EWMA-forecast next
  arrival, so a periodic tenant's first request of the burst lands on a
  live engine instead of paying the warm-restart latency.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.autoscale.metrics import MetricsWindow

#: fallback scale increment when no §9.3 history solution exists yet
#: (matches the runtime's 64 MiB sizing quantum)
DEFAULT_STEP_BYTES = 64 << 20


@dataclass(frozen=True)
class Decision:
    """One policy's verdict for one application this tick."""

    # none | scale_up | scale_down | park | unpark
    # | add_replica | remove_replica | grow_batch | shrink_batch
    action: str = "none"
    amount_bytes: int = 0       # for scale_up / scale_down
    reason: str = ""
    amount: int = 0             # for grow_batch / shrink_batch (new width)

    @property
    def is_action(self) -> bool:
        return self.action != "none"


NONE = Decision()


def sizing_step_bytes(handle) -> int:
    """The §9.3 solved incremental grant for this application -- the
    increment the paper says runtime growth should use -- else one
    allocation quantum."""
    sz = getattr(handle, "sizing", None)
    if sz is not None and sz.feasible and sz.step > 0:
        return int(sz.step)
    return DEFAULT_STEP_BYTES


class AppPolicy:
    """Per-application policy interface."""

    #: a parked app normally has nothing to decide (unparking is
    #: demand-driven); only policies that opt in here are consulted
    #: while the app is parked (see PredictiveUnparker)
    acts_on_parked = False

    def decide(self, window: MetricsWindow, handle) -> Decision:
        raise NotImplementedError


class TargetTracking(AppPolicy):
    """Track latency/denial targets; scale by the solved sizing step.

    Scale-up triggers on either windowed denial pressure (the pool said
    no) or windowed TTFT above target (requests waited).  Scale-down
    triggers only when the app is demonstrably over-provisioned: zero
    denial pressure, pool utilization under ``shrink_utilization``, and
    latency comfortably inside target.
    """

    def __init__(self, *, ttft_target_s: Optional[float] = None,
                 denial_target_per_s: float = 0.5,
                 shrink_utilization: float = 0.25,
                 max_demand_factor: float = 2.0):
        self.ttft_target_s = ttft_target_s
        self.denial_target_per_s = float(denial_target_per_s)
        self.shrink_utilization = float(shrink_utilization)
        self.max_demand_factor = float(max_demand_factor)

    def _up_headroom(self, handle) -> int:
        """Growth is target-tracking, not open-ended: never beyond
        ``max_demand_factor`` x the app's own demand estimate (an
        unbounded loop would grow bytes forever on a persistent denial
        signal the bytes cannot fix)."""
        cap = int(self.max_demand_factor
                  * handle.app.capped_demand(handle.app.estimate_demand()))
        return cap - handle.job.demand_bytes

    def decide(self, w: MetricsWindow, handle) -> Decision:
        step = sizing_step_bytes(handle)
        r = w.rates
        denials = r.get("denials_per_s", 0.0) or 0.0
        headroom = self._up_headroom(handle)
        if denials > self.denial_target_per_s and headroom > 0:
            return Decision("scale_up", min(step, headroom),
                            f"denials/s {denials:.2f} > "
                            f"{self.denial_target_per_s:.2f}")
        ttft = r.get("ttft_s")
        if (self.ttft_target_s is not None and ttft is not None
                and ttft > self.ttft_target_s and headroom > 0):
            return Decision("scale_up", min(step, headroom),
                            f"ttft {ttft * 1e3:.1f}ms > "
                            f"{self.ttft_target_s * 1e3:.1f}ms")
        util = r.get("pool_utilization")
        ttft_ok = (self.ttft_target_s is None or ttft is None
                   or ttft <= 0.5 * self.ttft_target_s)
        # propose shrink only while there is shrinkable headroom --
        # at the structural floor the decision would be a no-op that
        # shadows lower-priority policies (the idle parker) forever
        shrinkable = (handle.job.demand_bytes
                      - handle.app.structural_floor()) > 0
        # the denial signal is an EWMA: it decays geometrically and
        # never reaches exactly zero, so gate the shrink on a fraction
        # of the target rather than equality
        denials_quiet = denials <= 0.25 * self.denial_target_per_s
        if (denials_quiet and util is not None and shrinkable
                and util < self.shrink_utilization and ttft_ok):
            return Decision("scale_down", step,
                            f"utilization {util:.2f} < "
                            f"{self.shrink_utilization:.2f}")
        return NONE


class IdleParker(AppPolicy):
    """Park an app after ``idle_s`` with no traffic at all (empty queue,
    nothing running, no admissions/decodes observed)."""

    def __init__(self, idle_s: float = 60.0):
        self.idle_s = float(idle_s)

    def decide(self, w: MetricsWindow, handle) -> Decision:
        if getattr(handle, "parked", False):
            return NONE
        if (w.idle_s >= self.idle_s
                and w.rates.get("queue_len", 0) == 0
                and w.rates.get("num_running", 0) == 0):
            return Decision("park",
                            reason=f"idle {w.idle_s:.1f}s >= "
                                   f"{self.idle_s:.1f}s")
        return NONE


def _decode_occupancy(w: MetricsWindow, handle) -> Optional[float]:
    """Running requests / total decode slots (replicas x batch width),
    from the window's gauges.  None until the window has observed."""
    rset = getattr(handle, "replica_set", None)
    if rset is None:
        return None
    running = w.rates.get("num_running")
    if running is None:
        return None
    slots = len(rset.replicas) * max(rset.max_batch, 1)
    return float(running) / max(slots, 1)


class ReplicaScaler(AppPolicy):
    """Target-track windowed queue depth per replica (out) and decode
    occupancy (in), inside ``[max(min_replicas, 1), max_replicas]``.
    Scale-to-zero is NOT this policy's job: the IdleParker parks the
    whole app (min_replicas=0 merely permits it)."""

    def __init__(self, scale):
        self.scale = scale

    def decide(self, w: MetricsWindow, handle) -> Decision:
        rset = getattr(handle, "replica_set", None)
        if rset is None or getattr(handle, "parked", False):
            return NONE
        n = len(rset.replicas)
        qlen = w.rates.get("queue_len")
        if qlen is None:
            return NONE                  # no window observed yet
        per_replica = float(qlen) / max(n, 1)
        if (n < self.scale.max_replicas
                and per_replica > self.scale.target_queue_per_replica):
            return Decision(
                "add_replica",
                reason=f"queue/replica {per_replica:.1f} > "
                       f"{self.scale.target_queue_per_replica:.1f}")
        occ = _decode_occupancy(w, handle)
        if (n > max(self.scale.min_replicas, 1) and qlen == 0
                and occ is not None and occ < self.scale.shrink_occupancy):
            return Decision(
                "remove_replica",
                reason=f"occupancy {occ:.2f} < "
                       f"{self.scale.shrink_occupancy:.2f} across {n} "
                       "replicas")
        return NONE


class BatchScaler(AppPolicy):
    """Target-track decode occupancy with the continuous-batch width,
    doubling / halving inside ``[batch_min, batch_max]``.  The runners
    were compiled for ``batch_max`` up front (see
    ``JaxExecutor.build_replica``), so growing the width never
    retraces -- it only admits more."""

    def __init__(self, scale):
        self.scale = scale

    def decide(self, w: MetricsWindow, handle) -> Decision:
        rset = getattr(handle, "replica_set", None)
        if (rset is None or getattr(handle, "parked", False)
                or self.scale.batch_max is None):
            return NONE
        mb = rset.max_batch
        occ = _decode_occupancy(w, handle)
        if occ is None:
            return NONE
        qlen = w.rates.get("queue_len", 0)
        if (occ >= self.scale.grow_occupancy and qlen > 0
                and mb < self.scale.batch_max):
            return Decision(
                "grow_batch", reason=f"occupancy {occ:.2f} >= "
                f"{self.scale.grow_occupancy:.2f} with queue {qlen:.0f}",
                amount=min(mb * 2, self.scale.batch_max))
        if (occ <= self.scale.shrink_occupancy and mb > self.scale.batch_min
                and w.window.get("decode_steps", 0) > 0):
            return Decision(
                "shrink_batch", reason=f"occupancy {occ:.2f} <= "
                f"{self.scale.shrink_occupancy:.2f}",
                amount=max(mb // 2, self.scale.batch_min))
        return NONE


class PredictiveUnparker(AppPolicy):
    """Unpark ahead of the EWMA-forecast next arrival.

    The window tracks the smoothed gap between arrival-bearing
    observations (``arrival_gap_s``); when ``now + lead_s`` reaches the
    forecast next arrival -- and the forecast is not already stale by
    more than ``horizon`` gaps -- the parked app is warm-restarted so
    the burst's first request finds a live engine.  Purely an
    optimization: a wrong forecast costs one park/unpark cycle, never
    correctness (unparking stays demand-driven regardless)."""

    acts_on_parked = True

    def __init__(self, lead_s: float = 1.0, horizon: float = 1.5):
        self.lead_s = float(lead_s)
        self.horizon = float(horizon)

    def decide(self, w: MetricsWindow, handle) -> Decision:
        if not getattr(handle, "parked", False):
            return NONE
        gap = w.rates.get("arrival_gap_s")
        last = w.last_arrival_t
        if gap is None or gap <= 0 or last is None or w.now is None:
            return NONE
        due = last + gap
        if (w.now + self.lead_s >= due
                and w.now <= last + self.horizon * gap):
            return Decision(
                "unpark", reason=f"forecast arrival in "
                f"{max(due - w.now, 0.0):.2f}s (gap EWMA {gap:.2f}s)")
        return NONE


class QuotaRebalancer:
    """Demand-weighted fair-share quota resize across one pod's tenants.

    Per tick, each active (non-parked) view's demand is the EWMA of
    ``used pages + pages denied this window``.  Uncontended, every app
    gets demand x ``headroom`` (floored at ``min_pages``) -- so idle
    tenants' provisioned quota collapses toward the floor; contended
    (wants exceed the pool), the pool is split proportionally.  Shrinks
    below current usage drain via ``PoolView.resize_quota``'s preemption
    path, never stranding pages.
    """

    # 4 pages = 512 tokens: room for one typical request when an app has
    # no request history yet (shrinking a never-served app to less would
    # permanently reject its first arrival)
    def __init__(self, *, min_pages: int = 4, headroom: float = 1.5,
                 alpha: float = 0.5, floor_quantile: float = 0.9,
                 floor_requests: int = 2):
        self.min_pages = int(min_pages)
        self.headroom = float(headroom)
        self.alpha = float(alpha)
        self.floor_quantile = float(floor_quantile)
        self.floor_requests = int(floor_requests)
        self._demand: Dict[tuple, float] = {}   # (scope, app) -> EWMA pages

    def _floor_pages(self, shared, app: str) -> int:
        """An idle tenant's quota floor: enough pages for
        ``floor_requests`` x a ``floor_quantile`` request from this
        app's decayed history.  Shrinking below one request turns the
        next burst's arrivals into permanent admission rejections
        (``max_pages > quota``), which no later quota raise can undo;
        keeping a couple of requests' worth lets a burst's head admit
        immediately instead of waiting one reconcile round."""
        if shared.history is not None:
            h = shared.history.get(app, "request", "pages")
            if h is not None and h.count:
                return max(self.min_pages,
                           self.floor_requests
                           * math.ceil(h.quantile(self.floor_quantile)))
        return self.min_pages

    def rebalance(self, shared, windows: Dict[str, MetricsWindow], *,
                  scope: str = "") -> Dict[str, int]:
        """Resize quotas on ``shared`` for every app with a window.
        Returns the quotas applied (empty when fewer than two tenants --
        a lone tenant keeps whatever quota it was configured with).
        ``scope`` namespaces the demand EWMA: app names are unique only
        per pod, and one rebalancer instance serves every pod."""
        demands: Dict[str, float] = {}
        for app, view in shared.views.items():
            w = windows.get(app)
            if w is None or view.parked:
                continue
            denied = 0
            pool_delta = w.window.get("pool")
            if isinstance(pool_delta, dict):
                denied = pool_delta.get("denials", 0)
            d_now = float(view.used + denied)
            key = (scope, app)
            prev = self._demand.get(key, d_now)
            d = self.alpha * d_now + (1.0 - self.alpha) * prev
            self._demand[key] = d
            demands[app] = d
        if len(demands) < 2:
            return {}
        floors = {a: self._floor_pages(shared, a) for a in demands}
        want = {a: max(floors[a], math.ceil(d * self.headroom))
                for a, d in demands.items()}
        total_want = sum(want.values())
        n = shared.num_pages
        if total_want > n:               # contended: proportional split
            quotas = {a: max(floors[a], (n * wv) // total_want)
                      for a, wv in want.items()}
        else:                            # uncontended: demand + headroom
            quotas = {a: min(wv, n) for a, wv in want.items()}
        for app, q in quotas.items():
            shared.views[app].resize_quota(q)
        return quotas


def default_policies(*, ttft_target_s: Optional[float] = None,
                     denial_target_per_s: float = 0.5,
                     idle_park_s: float = 60.0,
                     scale=None) -> List[AppPolicy]:
    """The stock per-app policy chain.  The parker runs FIRST: the
    controller stops at the first active decision, and a large app can
    emit shrink decisions for many ticks (one sizing step each) -- an
    app that has crossed the idle threshold must park immediately, not
    after its bytes have been ground down to the floor.

    ``scale`` (a :class:`~repro.runtime.options.ScalePolicy`) appends
    the replica/batch scalers and predictive unparker after the parker
    but before byte-level target tracking: replica and width moves are
    cheaper and more reversible than byte grants, so they get first
    refusal on a pressure signal."""
    pols: List[AppPolicy] = [IdleParker(idle_s=idle_park_s)]
    if scale is not None:
        if scale.predictive_unpark:
            pols.append(PredictiveUnparker(lead_s=scale.unpark_lead_s))
        if scale.scales_replicas:
            pols.append(ReplicaScaler(scale))
        if scale.scales_batch:
            pols.append(BatchScaler(scale))
    pols.append(TargetTracking(ttft_target_s=ttft_target_s,
                               denial_target_per_s=denial_target_per_s))
    return pols
