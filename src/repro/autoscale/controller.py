"""The reconcile loop: ``Cluster.tick()`` drives one controller pass.

Closes the paper's central feedback loop -- the platform, not the
application, decides when resources grow, shrink, and get reclaimed:

    serving_stats() --> MetricsWindow --> policies --> Decision
                                                        |
         scale_up / scale_down / park  <-- hysteresis --+
         (AppHandle)                       + cooldowns

Design points:

* **windowed input** -- each attached app gets a
  :class:`~repro.autoscale.metrics.MetricsWindow`; the controller feeds
  it the raw cumulative ``serving_stats()`` each tick, so policies only
  ever see per-window rates.
* **hysteresis** -- a decision must repeat for ``confirm_ticks``
  consecutive ticks before it is applied (one noisy window never scales
  anything), and opposing streaks reset each other.
* **cooldowns** -- separate ``cooldown_up_s`` / ``cooldown_down_s``
  (shrinking is the dangerous direction: the paper's "avoid frequent
  small adjustments", §5.2.3).
* **scale-down floor** -- never below ``Application.structural_floor()``
  (params must stay resident; only the input-dependent share shrinks).
* **pod pass** -- after per-app decisions, the
  :class:`~repro.autoscale.policy.QuotaRebalancer` resizes co-tenant
  quotas on every pod's shared pool.

Time is injectable (``tick(now=...)``) so tests and the event-driven
benchmark drive the loop on a logical clock.
"""

from __future__ import annotations

import inspect
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.autoscale.metrics import MetricsWindow
from repro.autoscale.policy import (AppPolicy, Decision, QuotaRebalancer,
                                    default_policies)
from repro.obs import trace as obs_trace


@dataclass
class AppRecord:
    """Controller-side state for one attached application."""

    handle: object
    window: MetricsWindow
    policies: List[AppPolicy]
    streak: Dict[str, int] = field(default_factory=dict)
    last_up_t: float = float("-inf")
    last_down_t: float = float("-inf")


class AutoscaleController:
    """Owns metrics windows, policy evaluation, and actuation pacing."""

    def __init__(self, cluster, *,
                 make_policies=None,
                 rebalancer: Optional[QuotaRebalancer] = None,
                 rebalance_quotas: bool = True,
                 interval_s: float = 0.0,
                 cooldown_up_s: float = 0.0,
                 cooldown_down_s: float = 0.0,
                 confirm_ticks: int = 1,
                 window_alpha: float = 0.5):
        self.cluster = cluster
        self._make_policies = make_policies or default_policies
        self.rebalancer = rebalancer or QuotaRebalancer()
        self.rebalance_quotas = rebalance_quotas
        self.interval_s = float(interval_s)
        self.cooldown_up_s = float(cooldown_up_s)
        self.cooldown_down_s = float(cooldown_down_s)
        self.confirm_ticks = max(int(confirm_ticks), 1)
        self.window_alpha = float(window_alpha)
        self.apps: Dict[str, AppRecord] = {}
        self.log: List[Dict] = []
        self._last_tick: Optional[float] = None

    # -- membership ----------------------------------------------------------
    def attach(self, handle, policies: Optional[List[AppPolicy]] = None
               ) -> Optional[AppRecord]:
        """Manage one serve application (train apps are not autoscaled
        here: their growth path is compile-feedback escalation)."""
        if handle.app.kind != "serve":
            return None
        if policies is None:
            policies = self._call_make_policies(handle)
        rec = AppRecord(handle, MetricsWindow(alpha=self.window_alpha),
                        policies)
        self.apps[handle.job.job_id] = rec
        return rec

    def _call_make_policies(self, handle) -> List[AppPolicy]:
        """``make_policies`` may be per-app (takes the handle -- the
        default chain reads the app's ScalePolicy) or global (zero-arg,
        the pre-replica contract many callers still use)."""
        mk = self._make_policies
        try:
            sig = inspect.signature(mk)
            takes_handle = any(
                p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
                for p in sig.parameters.values())
        except (TypeError, ValueError):   # builtins, odd callables
            takes_handle = False
        return mk(handle) if takes_handle else mk()

    def detach(self, handle) -> None:
        self.apps.pop(handle.job.job_id, None)

    # -- the reconcile pass --------------------------------------------------
    def tick(self, now: Optional[float] = None) -> List[Dict]:
        """One control-plane round; returns the actions taken."""
        now = time.monotonic() if now is None else float(now)
        if (self._last_tick is not None and self.interval_s > 0
                and now - self._last_tick < self.interval_s):
            return []
        self._last_tick = now
        actions: List[Dict] = []
        for rec in list(self.apps.values()):
            h = rec.handle
            if h.state != "running":
                continue
            stats = h.serving_stats()
            if not stats:
                continue                 # engine not bound yet
            rec.window.observe(stats, now)
            # a parked app has almost nothing to decide: unparking is
            # demand-driven (submit_request), and letting scale policies
            # act on decaying pre-park signals would consume the park
            # reservation behind its back.  Only policies that opt in
            # via ``acts_on_parked`` (the predictive unparker) are
            # consulted -- _decide_and_apply filters the rest out.
            act = self._decide_and_apply(rec, now)
            if act is not None:
                actions.append(act)
        if self.rebalance_quotas:
            actions.extend(self._rebalance_pods())
        self.log.extend(actions)
        return actions

    def _decide_and_apply(self, rec: AppRecord, now: float
                          ) -> Optional[Dict]:
        decision = Decision()
        parked = getattr(rec.handle, "parked", False)
        for pol in rec.policies:
            if parked and not getattr(pol, "acts_on_parked", False):
                continue
            decision = pol.decide(rec.window, rec.handle)
            if decision.is_action:
                break
        if not decision.is_action:
            rec.streak.clear()
            return None
        # hysteresis: the SAME action for confirm_ticks consecutive ticks
        streak = rec.streak.get(decision.action, 0) + 1
        rec.streak = {decision.action: streak}
        if streak < self.confirm_ticks:
            return None
        act = self._apply(rec, decision, now)
        if act is not None:
            t = obs_trace.TRACER
            if t is not None:
                # the decision WITH its explanation: the rule that fired
                # and the windowed rates it saw this tick
                args = {"action": decision.action,
                        "reason": decision.reason}
                for k, v in rec.window.rates.items():
                    args["rate_" + k] = v
                t.instant("autoscale", "decision", rec.handle.app.name,
                          args)
        return act

    def _apply(self, rec: AppRecord, d: Decision, now: float
               ) -> Optional[Dict]:
        h = rec.handle
        entry = {"app": h.app.name, "action": d.action, "reason": d.reason,
                 "t": now}
        if d.action == "park":
            if h.parked:
                return None
            entry.update(h.park())
            rec.streak.clear()
            return entry
        if d.action == "unpark":
            if not h.parked:
                return None
            entry.update(h.unpark())
            rec.streak.clear()
            rec.last_up_t = now          # an unpark IS a scale-up event
            return entry
        if d.action == "add_replica":
            if now - rec.last_up_t < self.cooldown_up_s:
                return None
            h.add_replica()
            rec.last_up_t = now
            entry.update(num_replicas=h.num_replicas)
            return entry
        if d.action == "remove_replica":
            if now - rec.last_down_t < self.cooldown_down_s:
                return None
            receipt = h.remove_replica()
            rec.last_down_t = now
            entry.update(num_replicas=h.num_replicas, **receipt)
            return entry
        if d.action in ("grow_batch", "shrink_batch"):
            grow = d.action == "grow_batch"
            last = rec.last_up_t if grow else rec.last_down_t
            cool = self.cooldown_up_s if grow else self.cooldown_down_s
            if now - last < cool:
                return None
            applied = h.set_max_batch(d.amount)
            if grow:
                rec.last_up_t = now
            else:
                rec.last_down_t = now
            entry.update(max_batch=applied)
            return entry
        if d.action == "scale_up":
            if now - rec.last_up_t < self.cooldown_up_s:
                return None
            ok = h.scale_up(d.amount_bytes)
            if ok:
                rec.last_up_t = now
            entry.update(amount_bytes=d.amount_bytes, ok=ok)
            return entry
        if d.action == "scale_down":
            if now - rec.last_down_t < self.cooldown_down_s:
                return None
            floor = h.app.structural_floor()
            amount = min(d.amount_bytes,
                         max(h.job.demand_bytes - floor, 0))
            if amount <= 0:
                return None
            freed = h.scale_down(amount)
            rec.last_down_t = now
            entry.update(amount_bytes=amount, freed_bytes=freed)
            return entry
        return None

    def _rebalance_pods(self) -> List[Dict]:
        out = []
        for pod, pool in self.cluster._pod_pools.items():
            windows = {rec.handle.app.name: rec.window
                       for rec in self.apps.values()
                       if rec.handle.pod == pod}
            quotas = self.rebalancer.rebalance(pool, windows, scope=pod)
            if quotas:
                out.append({"action": "rebalance_quotas", "pod": pod,
                            "quotas": quotas})
                t = obs_trace.TRACER
                if t is not None:
                    t.instant("autoscale", "rebalance", pod,
                              {"quotas": dict(quotas)})
        return out

    # -- introspection -------------------------------------------------------
    def window_for(self, handle) -> Optional[MetricsWindow]:
        rec = self.apps.get(handle.job.job_id)
        return rec.window if rec else None
