"""repro.autoscale -- the resource-centric control plane.

Closes the feedback loop the paper's headline result rests on: the
platform watches each serve application's windowed signals
(:mod:`~repro.autoscale.metrics`), pluggable policies turn them into
scale/park decisions (:mod:`~repro.autoscale.policy`), a tick-driven
controller applies them with hysteresis and cooldowns
(:mod:`~repro.autoscale.controller`), and idle applications are parked
-- KV drained to host, pool pages and scheduler bytes released -- and
transparently unparked on the next request
(:mod:`~repro.autoscale.parking`).

Typical use::

    cluster = Cluster(pods=1, executor=JaxExecutor())
    cluster.enable_autoscale(idle_park_s=30.0)
    handle = cluster.submit(Application.serve(
        ..., serve=ServeOptions(quota_pages=32)))
    ...
    cluster.tick()          # one reconcile round (call from your loop)

An app that attaches a ``ScalePolicy`` to its ``ServeOptions`` also
gets replica-count and batch-width scaling (``ReplicaScaler``,
``BatchScaler``) and predictive unparking (``PredictiveUnparker``).
"""

from repro.autoscale.controller import AppRecord, AutoscaleController
from repro.autoscale.metrics import MetricsWindow, stats_delta
from repro.autoscale.parking import (ParkedApp, ParkedRequest, park_app,
                                     unpark_app)
from repro.autoscale.policy import (AppPolicy, BatchScaler, Decision,
                                    IdleParker, PredictiveUnparker,
                                    QuotaRebalancer, ReplicaScaler,
                                    TargetTracking, default_policies,
                                    sizing_step_bytes)

__all__ = [
    "AppPolicy", "AppRecord", "AutoscaleController", "BatchScaler",
    "Decision", "IdleParker", "MetricsWindow", "ParkedApp",
    "ParkedRequest", "PredictiveUnparker", "QuotaRebalancer",
    "ReplicaScaler", "TargetTracking", "default_policies", "park_app",
    "sizing_step_bytes", "stats_delta", "unpark_app",
]
