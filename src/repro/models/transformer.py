"""Model assembly: pattern blocks -> scanned stacks -> train/prefill/decode.

The layer stack is ``cfg.pattern`` repeated ``cfg.num_blocks`` times; the
scan body applies one pattern block (so heterogeneous stacks like gemma3's
5-local:1-global or zamba2's 5-mamba:1-shared-attn scan over *pattern
blocks*, keeping the HLO small and making per-block cost extrapolation
exact).

Weight-shared components (zamba2's shared attention) live outside the
scanned/stacked params, passed into the scan body by closure: one *data
component* feeding many *compute components* in resource-graph terms.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, ATTN_SHARED, DEC_ATTN,
                                ENC_ATTN, MAMBA2, MOE, RWKV6, ModelConfig)
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rw

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ImplConfig:
    """Execution-strategy knobs chosen by the materializer per invocation."""
    attn_impl: str = "naive"          # naive | chunked | pallas
    attn_chunk: int = 1024
    scan_chunk: int = 128             # rwkv/ssd chunk
    remat: str = "full"               # none | dots | full
    scan_blocks: bool = True          # scan vs unroll over pattern blocks
    num_blocks_override: Optional[int] = None  # cost-extrapolation probes
    unroll_blocks: bool = False       # fully unroll (cost pass)
    # (mesh, seq_axes, batch_axes) when the decode KV cache is seq-sharded
    decode_shard_ctx: Optional[tuple] = None
    # (mesh, model_axis, batch_axes) for expert-parallel MoE dispatch
    ep_shard_ctx: Optional[tuple] = None
    # stream the unembed+CE over sequence chunks (0 = monolithic logits)
    loss_chunk: int = 0
    # MoE dispatch: 'psum' (replicated-token combine) | 'a2a' (token-sharded
    # all-to-all exchange over the model axis)
    moe_dispatch: str = "psum"


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.remat(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.remat(fn)


# ---------------------------------------------------------------------------
# Norm dispatch (whisper uses LayerNorm+bias; the rest RMSNorm)
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    if cfg.family == "audio":
        return {"g": L.Spec((d,), ("embed",), std=1.0),
                "b": L.Spec((d,), ("embed",), std=0.0)}
    return {"g": L.rms_norm_spec(d)}


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.family == "audio":
        return L.layer_norm(x, p["g"], p["b"], eps=1e-5)
    return L.rms_norm(x, p["g"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Per-kind block param specs
# ---------------------------------------------------------------------------

def block_specs(cfg: ModelConfig, kind: str) -> Params:
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        return {"ln1": norm_specs(cfg), "attn": attn.attn_specs(cfg),
                "ln2": norm_specs(cfg),
                "mlp": L.gated_mlp_specs(cfg.d_model, cfg.d_ff)}
    if kind == ENC_ATTN:
        return {"ln1": norm_specs(cfg), "attn": attn.attn_specs(cfg),
                "ln2": norm_specs(cfg),
                "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff)}
    if kind == DEC_ATTN:
        return {"ln1": norm_specs(cfg),
                "attn": attn.attn_specs(cfg, cross=True),
                "ln_cross": norm_specs(cfg), "ln2": norm_specs(cfg),
                "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff)}
    if kind == MOE:
        return {"ln1": norm_specs(cfg), "attn": attn.attn_specs(cfg),
                "ln2": norm_specs(cfg), "moe": moe_mod.moe_specs(cfg)}
    if kind == RWKV6:
        return {"ln1": norm_specs(cfg), "ln2": norm_specs(cfg),
                "rwkv": rw.rwkv6_specs(cfg)}
    if kind == MAMBA2:
        return {"ln1": norm_specs(cfg), "mamba": m2.mamba2_specs(cfg)}
    if kind == ATTN_SHARED:
        # per-application params only (input norm); weights are shared
        return {"ln_in": norm_specs(cfg)}
    raise ValueError(kind)


def shared_specs(cfg: ModelConfig) -> Params:
    """Model-level components shared across blocks / frontends."""
    out: Params = {}
    if ATTN_SHARED in cfg.pattern:
        out["shared_attn"] = {
            "ln1": norm_specs(cfg), "attn": attn.attn_specs(cfg),
            "ln2": norm_specs(cfg),
            "mlp": L.gated_mlp_specs(cfg.d_model, cfg.d_ff)}
    if cfg.family == "vlm":
        out["img_proj"] = L.Spec((1024, cfg.d_model), (None, "embed"))
    if cfg.is_encdec:
        out["encoder"] = {
            "blocks": jax.tree.map(
                lambda s: L.Spec((cfg.num_encoder_layers,) + s.shape,
                                 ("blocks",) + s.axes, s.std),
                block_specs(cfg, ENC_ATTN), is_leaf=L.is_spec),
            "ln_f": norm_specs(cfg),
        }
    return out


def model_specs(cfg: ModelConfig) -> Params:
    """Full parameter spec tree."""
    nb = cfg.num_blocks

    def stack(s: L.Spec) -> L.Spec:
        return L.Spec((nb,) + s.shape, ("blocks",) + s.axes, s.std)

    blocks = {}
    for i, kind in enumerate(cfg.pattern):
        blocks[f"p{i}_{kind}"] = jax.tree.map(
            stack, block_specs(cfg, kind), is_leaf=L.is_spec)

    out: Params = {
        "embed": L.embed_specs(cfg.vocab_size, cfg.d_model,
                               cfg.tie_embeddings),
        "blocks": blocks,
        "ln_f": norm_specs(cfg),
    }
    out.update(shared_specs(cfg))
    return out


# ---------------------------------------------------------------------------
# Block application (train / prefill / decode)
# ---------------------------------------------------------------------------

def _attn_mlp_block(cfg: ModelConfig, impl: ImplConfig, p: Params,
                    x: jax.Array, *, window: int, gated: bool = True
                    ) -> jax.Array:
    h = apply_norm(cfg, p["ln1"], x)
    x = x + attn.self_attention_train(
        p["attn"], h, cfg, causal=True, window=window,
        impl=impl.attn_impl, chunk=impl.attn_chunk)
    h = apply_norm(cfg, p["ln2"], x)
    if gated:
        x = x + L.gated_mlp(p["mlp"], h)
    else:
        x = x + L.mlp(p["mlp"], h)
    return x


def apply_block_train(cfg: ModelConfig, impl: ImplConfig, kind: str,
                      p: Params, x: jax.Array, shared: Params,
                      enc_out: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        x = _attn_mlp_block(cfg, impl, p, x, window=window)
    elif kind == DEC_ATTN:
        h = apply_norm(cfg, p["ln1"], x)
        x = x + attn.self_attention_train(
            p["attn"], h, cfg, causal=True, impl=impl.attn_impl,
            chunk=impl.attn_chunk, prefix="self_")
        h = apply_norm(cfg, p["ln_cross"], x)
        enc_kv = attn.encode_cross_kv(p["attn"], enc_out)
        x = x + attn.cross_attention(p["attn"], h, enc_kv, cfg)
        h = apply_norm(cfg, p["ln2"], x)
        x = x + L.mlp(p["mlp"], h)
    elif kind == MOE:
        h = apply_norm(cfg, p["ln1"], x)
        x = x + attn.self_attention_train(
            p["attn"], h, cfg, causal=True, impl=impl.attn_impl,
            chunk=impl.attn_chunk)
        h = apply_norm(cfg, p["ln2"], x)
        y, aux = moe_mod.moe_block(p["moe"], h, cfg,
                                   shard_ctx=impl.ep_shard_ctx,
                                   dispatch=impl.moe_dispatch)
        x = x + y
    elif kind == RWKV6:
        h = apply_norm(cfg, p["ln1"], x)
        x = x + rw.time_mix_train(p["rwkv"], h, cfg, chunk=impl.scan_chunk)
        h = apply_norm(cfg, p["ln2"], x)
        x = x + rw.channel_mix(p["rwkv"], h)
    elif kind == MAMBA2:
        h = apply_norm(cfg, p["ln1"], x)
        x = x + m2.mamba2_train(p["mamba"], h, cfg, chunk=impl.scan_chunk)
    elif kind == ATTN_SHARED:
        sp = shared["shared_attn"]
        h = apply_norm(cfg, p["ln_in"], x)
        x = x + _shared_attn_apply(cfg, impl, sp, h)
    else:
        raise ValueError(kind)
    return x, aux


def _shared_attn_apply(cfg: ModelConfig, impl: ImplConfig, sp: Params,
                       x: jax.Array) -> jax.Array:
    h = apply_norm(cfg, sp["ln1"], x)
    y = attn.self_attention_train(sp["attn"], h, cfg, causal=True,
                                  impl=impl.attn_impl, chunk=impl.attn_chunk)
    h2 = apply_norm(cfg, sp["ln2"], x + y)
    return y + L.gated_mlp(sp["mlp"], h2)


# ---------------------------------------------------------------------------
# Cache specs per kind
# ---------------------------------------------------------------------------

def block_cache_specs(cfg: ModelConfig, kind: str, batch: int,
                      cache_len: int):
    if kind in (ATTN_GLOBAL, MOE, ATTN_SHARED):
        return attn.kv_cache_specs(cfg, batch, cache_len)
    if kind == ATTN_LOCAL:
        return attn.kv_cache_specs(cfg, batch, cache_len,
                                   window=cfg.sliding_window)
    if kind == DEC_ATTN:
        specs = attn.kv_cache_specs(cfg, batch, cache_len)
        kvs = (batch, cfg.num_kv_heads, cfg.encoder_seq_len, cfg.head_dim)
        specs["cross_k"] = jax.ShapeDtypeStruct(kvs, jnp.bfloat16)
        specs["cross_v"] = jax.ShapeDtypeStruct(kvs, jnp.bfloat16)
        return specs
    if kind == RWKV6:
        return rw.rwkv_state_specs(cfg, batch)
    if kind == MAMBA2:
        return m2.mamba_state_specs(cfg, batch)
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    """Stacked (num_blocks leading dim) cache spec tree."""
    nb = cfg.num_blocks
    out = {}
    for i, kind in enumerate(cfg.pattern):
        leaf = block_cache_specs(cfg, kind, batch, cache_len)
        out[f"p{i}_{kind}"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((nb,) + s.shape, s.dtype), leaf)
    return out


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, cache_len))


# ---------------------------------------------------------------------------
# Decode-step block application
# ---------------------------------------------------------------------------

def apply_block_decode(cfg: ModelConfig, impl: ImplConfig, kind: str,
                       p: Params, x: jax.Array, cache: Params,
                       pos: jax.Array, shared: Params
                       ) -> Tuple[jax.Array, Params]:
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        h = apply_norm(cfg, p["ln1"], x)
        y, cache = attn.self_attention_decode(p["attn"], h, cache, pos, cfg,
                                              window=window,
                                              shard_ctx=impl.decode_shard_ctx)
        x = x + y
        h = apply_norm(cfg, p["ln2"], x)
        x = x + L.gated_mlp(p["mlp"], h)
    elif kind == DEC_ATTN:
        h = apply_norm(cfg, p["ln1"], x)
        self_cache = {"k": cache["k"], "v": cache["v"]}
        y, self_cache = attn.self_attention_decode(
            p["attn"], h, self_cache, pos, cfg, prefix="self_",
            shard_ctx=impl.decode_shard_ctx)
        x = x + y
        h = apply_norm(cfg, p["ln_cross"], x)
        q = jnp.einsum("bsd,dnh->bsnh", h, p["attn"]["cross_wq"])
        t_enc = cache["cross_k"].shape[2]
        o = attn.gqa_decode_sdpa(q, cache["cross_k"], cache["cross_v"],
                                 jnp.ones((t_enc,), bool))
        x = x + attn.attn_out(p["attn"], o, prefix="cross_")
        h = apply_norm(cfg, p["ln2"], x)
        x = x + L.mlp(p["mlp"], h)
        cache = dict(self_cache, cross_k=cache["cross_k"],
                     cross_v=cache["cross_v"])
    elif kind == MOE:
        h = apply_norm(cfg, p["ln1"], x)
        y, cache = attn.self_attention_decode(p["attn"], h, cache, pos, cfg,
                                              shard_ctx=impl.decode_shard_ctx)
        x = x + y
        h = apply_norm(cfg, p["ln2"], x)
        y, _ = moe_mod.moe_block(p["moe"], h, cfg,
                                 shard_ctx=impl.ep_shard_ctx,
                                 dispatch=impl.moe_dispatch)
        x = x + y
    elif kind == RWKV6:
        h = apply_norm(cfg, p["ln1"], x)
        y, cache = _rwkv_decode(p["rwkv"], h, cache, cfg)
        x = x + y
        h = apply_norm(cfg, p["ln2"], x)
        cm = rw.channel_mix(p["rwkv"], h, cache["shift_c"])
        cache = dict(cache, shift_c=h)
        x = x + cm
    elif kind == MAMBA2:
        h = apply_norm(cfg, p["ln1"], x)
        y, cache = m2.mamba2_decode(p["mamba"], h, cache, cfg)
        x = x + y
    elif kind == ATTN_SHARED:
        sp = shared["shared_attn"]
        h = apply_norm(cfg, p["ln_in"], x)
        hh = apply_norm(cfg, sp["ln1"], h)
        y, cache = attn.self_attention_decode(sp["attn"], hh, cache, pos, cfg,
                                              shard_ctx=impl.decode_shard_ctx)
        h2 = apply_norm(cfg, sp["ln2"], h + y)
        x = x + y + L.gated_mlp(sp["mlp"], h2)
    else:
        raise ValueError(kind)
    return x, cache


def _rwkv_decode(p, x, cache, cfg):
    tm_state = {"wkv": cache["wkv"], "shift_t": cache["shift_t"],
                "shift_c": cache["shift_c"]}
    y, tm_state = rw.time_mix_decode(p, x, tm_state, cfg)
    return y, dict(cache, **tm_state)


# ---------------------------------------------------------------------------
# Prefill-mode block application (full forward, returns populated cache)
# ---------------------------------------------------------------------------

def apply_block_prefill(cfg: ModelConfig, impl: ImplConfig, kind: str,
                        p: Params, x: jax.Array, shared: Params,
                        enc_out: Optional[jax.Array], cache_len: int
                        ) -> Tuple[jax.Array, Params]:
    s = x.shape[1]
    if kind in (ATTN_GLOBAL, ATTN_LOCAL, MOE):
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        h = apply_norm(cfg, p["ln1"], x)
        y, kv = attn.self_attention_prefill(
            p["attn"], h, cfg, window=window, impl=impl.attn_impl,
            chunk=impl.attn_chunk)
        kv = _pad_cache(kv, cache_len, window)
        x = x + y
        h = apply_norm(cfg, p["ln2"], x)
        if kind == MOE:
            y, _ = moe_mod.moe_block(p["moe"], h, cfg,
                                     shard_ctx=impl.ep_shard_ctx,
                                     dispatch=impl.moe_dispatch)
            x = x + y
        else:
            x = x + L.gated_mlp(p["mlp"], h)
        return x, kv
    if kind == DEC_ATTN:
        h = apply_norm(cfg, p["ln1"], x)
        y, kv = attn.self_attention_prefill(
            p["attn"], h, cfg, impl=impl.attn_impl, chunk=impl.attn_chunk,
            prefix="self_")
        kv = _pad_cache(kv, cache_len, 0)
        x = x + y
        h = apply_norm(cfg, p["ln_cross"], x)
        enc_kv = attn.encode_cross_kv(p["attn"], enc_out)
        x = x + attn.cross_attention(p["attn"], h, enc_kv, cfg)
        h = apply_norm(cfg, p["ln2"], x)
        x = x + L.mlp(p["mlp"], h)
        return x, dict(kv, cross_k=enc_kv["k"].transpose(0, 2, 1, 3),
                       cross_v=enc_kv["v"].transpose(0, 2, 1, 3))
    if kind == RWKV6:
        h = apply_norm(cfg, p["ln1"], x)
        hh = h
        r, k, v, g, logw = rw.time_mix_projections(p["rwkv"], hh, None, cfg)
        b = x.shape[0]
        state0 = jnp.zeros((b, cfg.num_heads, cfg.head_dim, cfg.head_dim),
                           jnp.float32)
        o, wkv = rw.wkv_chunked(r, k, v, logw, p["rwkv"]["bonus_u"], state0,
                                impl.scan_chunk)
        from repro.models.layers import group_norm_heads
        o = group_norm_heads(o.astype(x.dtype), p["rwkv"]["ln_x"])
        o = o * jax.nn.silu(g)
        x = x + jnp.einsum("bsnh,nhd->bsd", o, p["rwkv"]["wo"])
        h2 = apply_norm(cfg, p["ln2"], x)
        x = x + rw.channel_mix(p["rwkv"], h2)
        cache = {"wkv": wkv, "shift_t": hh[:, -1:], "shift_c": h2[:, -1:]}
        return x, cache
    if kind == MAMBA2:
        h = apply_norm(cfg, p["ln1"], x)
        bsz = x.shape[0]
        d_inner, nh, p_dim, n = m2.mamba_dims(cfg)
        z, xh, b_in, c_in, dt, conv_state = m2._projections(
            p["mamba"], h, cfg, None)
        xh_r = xh.reshape(bsz, s, nh, p_dim)
        st0 = jnp.zeros((bsz, nh, p_dim, n), jnp.float32)
        y, ssm = m2.ssd_chunked(xh_r, dt, p["mamba"]["a_log"], b_in, c_in,
                                st0, impl.scan_chunk)
        y = y + xh_r.astype(jnp.float32) * \
            p["mamba"]["d_skip"].astype(jnp.float32)[:, None]
        y = y.reshape(bsz, s, d_inner).astype(x.dtype)
        y = L.rms_norm(y * jax.nn.silu(z), p["mamba"]["norm"], cfg.norm_eps)
        x = x + jnp.einsum("bsi,id->bsd", y, p["mamba"]["w_out"])
        return x, {"ssm": ssm, "conv": conv_state}
    if kind == ATTN_SHARED:
        sp = shared["shared_attn"]
        h = apply_norm(cfg, p["ln_in"], x)
        hh = apply_norm(cfg, sp["ln1"], h)
        y, kv = attn.self_attention_prefill(
            sp["attn"], hh, cfg, impl=impl.attn_impl, chunk=impl.attn_chunk)
        kv = _pad_cache(kv, cache_len, 0)
        h2 = apply_norm(cfg, sp["ln2"], h + y)
        x = x + y + L.gated_mlp(sp["mlp"], h2)
        return x, kv
    raise ValueError(kind)


def _pad_cache(kv: Params, cache_len: int, window: int) -> Params:
    """Right-pad prefill kv ((B, KV, S, hd) layout) to the cache length
    (ring layout for SWA)."""
    target = min(cache_len, window) if window > 0 else cache_len
    def pad(a):
        s = a.shape[2]
        if s == target:
            return a
        if s > target:
            return a[:, :, :target]
        return jnp.pad(a, ((0, 0), (0, 0), (0, target - s), (0, 0)))
    return jax.tree.map(pad, kv)
