"""Mamba-2 block (SSD: state-space duality, chunked algorithm).

Selective SSM with scalar-per-head decay:

    h_t = exp(a_t) h_{t-1} + dt_t * B_t x_t^T      (h: (H, P, N))
    y_t = C_t h_t + D x_t

with a_t = -exp(A_log) * dt_t, dt_t = softplus(dt_raw + dt_bias).

Training/prefill uses the chunked SSD form: intra-chunk attention-like term
plus inter-chunk state carry (scan over chunks).  Decode is the single-step
recurrence, so the state is constant-size (long_500k runs).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Spec, rms_norm

Params = Dict[str, Any]


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    return d_inner, n_heads, ssm.head_dim, ssm.state_dim


def mamba2_specs(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner, h, p_dim, n = mamba_dims(cfg)
    conv_dim = d_inner + 2 * n  # x, B, C share the depthwise conv
    return {
        "w_in_z": Spec((d, d_inner), ("embed", "ssm_inner")),
        "w_in_x": Spec((d, d_inner), ("embed", "ssm_inner")),
        "w_in_b": Spec((d, n), ("embed", "ssm_state")),
        "w_in_c": Spec((d, n), ("embed", "ssm_state")),
        "w_in_dt": Spec((d, h), ("embed", "ssm_heads")),
        "conv_w": Spec((cfg.ssm.conv_width, conv_dim), ("conv_w", "ssm_conv")),
        "conv_b": Spec((conv_dim,), ("ssm_conv",), std=0.0),
        "a_log": Spec((h,), ("ssm_heads",), std=0.02),
        "dt_bias": Spec((h,), ("ssm_heads",), std=0.02),
        "d_skip": Spec((h,), ("ssm_heads",), std=0.02),
        "norm": Spec((d_inner,), ("ssm_inner",), std=0.0),
        "w_out": Spec((d_inner, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x: (B, S, C); w: (K, C).  Returns
    (y, new_conv_state (B, K-1, C))."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return jax.nn.silu(y + b), new_state


def ssd_chunked(xh: jax.Array, dt: jax.Array, a_log: jax.Array,
                b_in: jax.Array, c_in: jax.Array, state: jax.Array,
                chunk: int) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh: (B, S, H, P); dt: (B, S, H) fp32; b_in, c_in: (B, S, N);
    state: (B, H, P, N) fp32.  Returns (y (B,S,H,P), new_state).
    """
    bsz, s, h, p_dim = xh.shape
    n = b_in.shape[-1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        # zero-pad: dt=0 => no state update and a=0 => no decay
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nchunks = s // c
    CLAMP = -30.0

    a = (-jnp.exp(a_log.astype(jnp.float32)))[None, None, :] * dt  # (B,S,H)

    def per_chunk(state, inp):
        xc, dtc, ac, bc, cc = inp  # (B,C,H,P), (B,C,H), (B,C,H), (B,C,N) x2
        csum = jnp.cumsum(ac, axis=1)                       # (B,C,H) inclusive
        total = csum[:, -1:]                                # (B,1,H)
        dec_in = jnp.exp(jnp.maximum(csum, CLAMP))          # decay through t
        dec_out = jnp.exp(jnp.maximum(total - csum, CLAMP))
        x32 = xc.astype(jnp.float32)
        b32 = bc.astype(jnp.float32)
        c32 = cc.astype(jnp.float32)

        # inter-chunk: y_inter[t] = dec_in[t] * C_t @ state
        ch = jnp.einsum("bcn,bhpn->bchp", c32, state)
        y_inter = ch * dec_in[..., None]

        # intra-chunk: y[t] += sum_{s<=t} exp(csum[t]-csum[s]) dt_s
        #                       (C_t . B_s) x_s
        att = jnp.einsum("bcn,bsn->bcs", c32, b32)          # (B,C,C)
        pair = jnp.exp(jnp.clip(csum[:, :, None, :] - csum[:, None, :, :],
                                CLAMP, -CLAMP))             # (B,C,C,H)
        tri = jnp.tril(jnp.ones((c, c), jnp.float32))
        w = att[..., None] * pair * tri[None, :, :, None]   # (B,C,C,H)
        y_intra = jnp.einsum("bcsh,bsh,bshp->bchp", w, dtc, x32)

        # state update
        kdec = (dtc * dec_out)[..., None] * b32[:, :, None, :]  # (B,C,H,N)
        new_state = state * jnp.exp(jnp.maximum(total, 2 * CLAMP))[:, 0, :, None, None] \
            + jnp.einsum("bchn,bchp->bhpn", kdec, x32)
        return new_state, y_inter + y_intra

    xs = (xh.reshape(bsz, nchunks, c, h, p_dim).transpose(1, 0, 2, 3, 4),
          dt.reshape(bsz, nchunks, c, h).transpose(1, 0, 2, 3),
          a.reshape(bsz, nchunks, c, h).transpose(1, 0, 2, 3),
          b_in.reshape(bsz, nchunks, c, n).transpose(1, 0, 2, 3),
          c_in.reshape(bsz, nchunks, c, n).transpose(1, 0, 2, 3))
    state, y = jax.lax.scan(jax.remat(per_chunk), state, xs)
    y = y.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p_dim)
    if pad:
        y = y[:, : s - pad]
    return y, state


def mamba_state_specs(cfg: ModelConfig, batch: int):
    d_inner, h, p_dim, n = mamba_dims(cfg)
    conv_dim = d_inner + 2 * n
    k = cfg.ssm.conv_width
    return {
        "ssm": jax.ShapeDtypeStruct((batch, h, p_dim, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, k - 1, conv_dim), jnp.bfloat16),
    }


def init_mamba_state(cfg: ModelConfig, batch: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        mamba_state_specs(cfg, batch))


def _projections(p: Params, x: jax.Array, cfg: ModelConfig,
                 conv_state: Optional[jax.Array]):
    d_inner, h, p_dim, n = mamba_dims(cfg)
    z = jnp.einsum("bsd,di->bsi", x, p["w_in_z"])
    xbc = jnp.concatenate([
        jnp.einsum("bsd,di->bsi", x, p["w_in_x"]),
        jnp.einsum("bsd,dn->bsn", x, p["w_in_b"]),
        jnp.einsum("bsd,dn->bsn", x, p["w_in_c"])], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xh = xbc[..., :d_inner]
    b_in = xbc[..., d_inner: d_inner + n]
    c_in = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_in_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return z, xh, b_in, c_in, dt, new_conv


def mamba2_train(p: Params, x: jax.Array, cfg: ModelConfig,
                 chunk: int = 128) -> jax.Array:
    bsz, s, _ = x.shape
    d_inner, h, p_dim, n = mamba_dims(cfg)
    z, xh, b_in, c_in, dt, _ = _projections(p, x, cfg, None)
    xh = xh.reshape(bsz, s, h, p_dim)
    state = jnp.zeros((bsz, h, p_dim, n), jnp.float32)
    y, _ = ssd_chunked(xh, dt, p["a_log"], b_in, c_in, state, chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return jnp.einsum("bsi,id->bsd", y, p["w_out"])


def mamba2_decode(p: Params, x: jax.Array, state: Dict[str, jax.Array],
                  cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, D); single-step SSM recurrence."""
    bsz = x.shape[0]
    d_inner, h, p_dim, n = mamba_dims(cfg)
    z, xh, b_in, c_in, dt, new_conv = _projections(
        p, x, cfg, state["conv"])
    xh32 = xh.reshape(bsz, h, p_dim).astype(jnp.float32)
    dt1 = dt[:, 0]                                            # (B,H)
    a = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32))[None] * dt1)  # (B,H)
    b32 = b_in[:, 0].astype(jnp.float32)                      # (B,N)
    c32 = c_in[:, 0].astype(jnp.float32)
    upd = (dt1[..., None, None] * xh32[..., None]
           * b32[:, None, None, :])                            # (B,H,P,N)
    new_ssm = state["ssm"] * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, c32)
    y = y + xh32 * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return out, {"ssm": new_ssm, "conv": new_conv}
