"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

The defining Finch feature is the *data-dependent* per-channel decay
``w_t = exp(-exp(w0 + tanh(x~ W_a) W_b))`` entering a linear-attention
recurrence with per-head state S (hd x hd):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Training uses a chunked parallel form (scan over chunks carrying S); decode
is the single-step recurrence (constant-size state => long_500k runs).
Simplification noted in DESIGN.md: the token-shift mix coefficients are
plain learned vectors (the small mix-LoRA of the full Finch block is
omitted); the decay LoRA -- the paper-relevant data dependence -- is kept.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Spec, group_norm_heads

Params = Dict[str, Any]

DECAY_LORA = 64


def rwkv6_specs(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    hd = cfg.head_dim
    f = cfg.d_ff
    return {
        # time-mix
        "mu": Spec((5, d), (None, "embed"), std=0.02),      # r,k,v,w,g shifts
        "wr": Spec((d, h, hd), ("embed", "q_heads", "head_dim")),
        "wk": Spec((d, h, hd), ("embed", "q_heads", "head_dim")),
        "wv": Spec((d, h, hd), ("embed", "q_heads", "head_dim")),
        "wg": Spec((d, h, hd), ("embed", "q_heads", "head_dim")),
        "wo": Spec((h, hd, d), ("q_heads", "head_dim", "embed")),
        "w0": Spec((h, hd), ("q_heads", "head_dim"), std=0.02),
        "wa": Spec((d, DECAY_LORA), ("embed", "lora")),        # decay LoRA in
        "wb": Spec((DECAY_LORA, h, hd), ("lora", "q_heads", "head_dim")),
        "bonus_u": Spec((h, hd), ("q_heads", "head_dim"), std=0.02),
        "ln_x": Spec((h, hd), ("q_heads", "head_dim"), std=1.0),
        # channel-mix
        "mu_c": Spec((2, d), (None, "embed"), std=0.02),
        "ck": Spec((d, f), ("embed", "ffn")),
        "cv": Spec((f, d), ("ffn", "embed")),
        "cr": Spec((d, d), ("embed", "embed2")),
    }


def token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """Shift sequence right by one; `prev` is the last token of the previous
    segment (decode carry), defaults to zeros."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xx, mu):
    return x + (xx - x) * mu


def decay_logw(p: Params, xw: jax.Array) -> jax.Array:
    """Data-dependent decay: log(w_t) in (-inf, 0).  xw: (B,S,D) ->
    (B,S,H,hd) fp32."""
    lora = jnp.einsum("bsd,dl->bsl", xw, p["wa"])
    delta = jnp.einsum("bsl,lnh->bsnh", jnp.tanh(lora), p["wb"])
    raw = p["w0"].astype(jnp.float32) + delta.astype(jnp.float32)
    return -jnp.exp(raw)  # log w_t = -exp(.) in (-inf, 0) => w in (0, 1)


def time_mix_projections(p: Params, x: jax.Array, x_prev: Optional[jax.Array],
                         cfg: ModelConfig):
    xx = token_shift(x, x_prev)
    mu = p["mu"]
    xr, xk, xv, xw, xg = (_mix(x, xx, mu[i]) for i in range(5))
    r = jnp.einsum("bsd,dnh->bsnh", xr, p["wr"])
    k = jnp.einsum("bsd,dnh->bsnh", xk, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", xv, p["wv"])
    g = jnp.einsum("bsd,dnh->bsnh", xg, p["wg"])
    logw = decay_logw(p, xw)                                   # fp32
    return r, k, v, g, logw


def wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Chunked linear attention with per-token decay.

    r,k,v: (B,S,H,hd); logw: (B,S,H,hd) fp32; u: (H,hd);
    state: (B,H,hd,hd) fp32.  Returns (o (B,S,H,hd), new_state).
    """
    b, s, h, hd = r.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        # zero-pad to a chunk multiple: k=v=0 contributes nothing and
        # logw=0 (w=1) leaves the state untouched on padded steps
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    n = s // c

    # Exponent clamp: pairwise decays below exp(CLAMP) saturate to
    # exp(CLAMP) ~ 1e-13 instead of under/overflowing the ratio trick.
    CLAMP = -30.0

    def per_chunk(state, inp):
        rc, kc, vc, lwc = inp                                   # (B,C,H,hd)
        lw32 = lwc.astype(jnp.float32)
        csum = jnp.cumsum(lw32, axis=1)                         # inclusive
        total = csum[:, -1:]                                    # (B,1,H,hd)
        # decay from chunk start through token t-1 (exclusive cumsum)
        dec_in = jnp.exp(jnp.maximum(csum - lw32, CLAMP))       # (B,C,H,hd)
        # decay from just AFTER token t through chunk end
        dec_out = jnp.exp(jnp.maximum(total - csum, CLAMP))
        r32 = rc.astype(jnp.float32)
        k32 = kc.astype(jnp.float32)
        v32 = vc.astype(jnp.float32)

        # inter-chunk: o_inter[t] = (r_t * dec_in[t]) @ state
        o_inter = jnp.einsum("bcnh,bnhp->bcnp", r32 * dec_in, state)

        # intra-chunk: pairwise decay  prod_{i in (s, t)} w_i  for s < t
        # = exp(csum[t-1] - csum[s]) = dec_in[t] / exp(csum[s])  per channel:
        # attn[t, s] = sum_h r[t,h] dec_in[t,h] * k[s,h] exp(-csum[s,h])
        # plus the bonus-u diagonal term (s == t).
        rd = r32 * dec_in                                       # (B,C,H,hd)
        kd = k32 * jnp.exp(jnp.clip(-csum, CLAMP, -CLAMP))
        att = jnp.einsum("bcnh,bsnh->bncs", rd, kd)             # (B,H,C,C)
        tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)
        att = att * tri
        diag = jnp.einsum("bcnh,bcnh->bnc", r32, k32 * u.astype(jnp.float32))
        att = att + jnp.einsum("bnc,cs->bncs", diag, jnp.eye(c, dtype=jnp.float32))
        o_intra = jnp.einsum("bncs,bsnp->bcnp", att, v32)

        # state update: S' = diag(prod w) S + sum_s dec_out[s] k_s^T v_s
        kdec = k32 * dec_out
        new_state = state * jnp.exp(jnp.maximum(total, 2 * CLAMP))[:, 0, :, :, None] + \
            jnp.einsum("bcnh,bcnp->bnhp", kdec, v32)
        return new_state, (o_inter + o_intra)

    rs = r.reshape(b, n, c, h, hd).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(b, n, c, h, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n, c, h, hd).transpose(1, 0, 2, 3, 4)
    ls = logw.reshape(b, n, c, h, hd).transpose(1, 0, 2, 3, 4)
    state, o = jax.lax.scan(jax.remat(per_chunk), state, (rs, ks, vs, ls))
    o = o.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    if pad:
        o = o[:, : s - pad]
    return o, state


def time_mix_train(p: Params, x: jax.Array, cfg: ModelConfig,
                   chunk: int = 128) -> jax.Array:
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    r, k, v, g, logw = time_mix_projections(p, x, None, cfg)
    state = jnp.zeros((b, h, hd, hd), jnp.float32)
    o, _ = wkv_chunked(r, k, v, logw, p["bonus_u"], state, chunk)
    o = group_norm_heads(o.astype(x.dtype), p["ln_x"])
    o = o * jax.nn.silu(g)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"])


def rwkv_state_specs(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, hd = cfg.num_heads, cfg.head_dim
    d = cfg.d_model
    return {
        "wkv": jax.ShapeDtypeStruct((batch, h, hd, hd), jnp.float32),
        "shift_t": jax.ShapeDtypeStruct((batch, 1, d), jnp.bfloat16),
        "shift_c": jax.ShapeDtypeStruct((batch, 1, d), jnp.bfloat16),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        rwkv_state_specs(cfg, batch))


def time_mix_decode(p: Params, x: jax.Array, state: Dict[str, jax.Array],
                    cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, D).  Single-step recurrence."""
    h, hd = cfg.num_heads, cfg.head_dim
    r, k, v, g, logw = time_mix_projections(p, x, state["shift_t"], cfg)
    r32, k32, v32 = (t.astype(jnp.float32)[:, 0] for t in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))[:, 0]                # (B,H,hd)
    u = p["bonus_u"].astype(jnp.float32)
    s_old = state["wkv"]                                        # (B,H,hd,hd)
    kv = jnp.einsum("bnh,bnp->bnhp", k32, v32)
    o = jnp.einsum("bnh,bnhp->bnp", r32, s_old + u[None, :, :, None] * kv)
    s_new = s_old * w[..., None] + kv
    o = o[:, None].astype(x.dtype)                              # (B,1,H,hd)
    o = group_norm_heads(o, p["ln_x"]) * jax.nn.silu(g)
    out = jnp.einsum("bsnh,nhd->bsd", o, p["wo"])
    new_state = dict(state, wkv=s_new, shift_t=x)
    return out, new_state


def channel_mix(p: Params, x: jax.Array,
                x_prev: Optional[jax.Array] = None) -> jax.Array:
    xx = token_shift(x, x_prev)
    mu = p["mu_c"]
    xk = _mix(x, xx, mu[0])
    xr = _mix(x, xx, mu[1])
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["ck"])))
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["cr"]).astype(jnp.float32))
    return rr.astype(x.dtype) * jnp.einsum("bsf,fd->bsd", kk, p["cv"])
