"""Attention: GQA self/cross attention for train, prefill and decode.

Three execution strategies (the materializer picks per invocation class,
mirroring the paper's local-vs-remote compilation versions):

* ``naive``   -- full (S x S) score materialization.  Cheapest HLO for short
                 sequences; O(S^2) activation memory.
* ``chunked`` -- online-softmax scan over query chunks (flash-attention
                 algorithm in pure jnp).  O(S * chunk) activation memory;
                 the jnp oracle for the Pallas flash kernel.
* Pallas flash kernel (kernels/flash_attention.py) -- TPU target; dispatched
  via kernels/ops.py when enabled.

Decode uses a KV cache: full-length for global attention, ring buffer of
window size for sliding-window attention (bounds gemma3's long_500k KV).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.layers import Spec, apply_rope, rms_norm, rms_norm_spec

Params = Dict[str, Any]

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig, cross: bool = False) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    std = 0.02
    p = {
        "wq": Spec((d, h, hd), ("embed", "q_heads", "head_dim"), std),
        "wk": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim"), std),
        "wv": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim"), std),
        "wo": Spec((h, hd, d), ("q_heads", "head_dim", "embed"), std),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = rms_norm_spec(hd)
        p["k_norm"] = rms_norm_spec(hd)
    if cross:
        p = {f"self_{k}": v for k, v in p.items()}
        p.update({
            "cross_wq": Spec((d, h, hd), ("embed", "q_heads", "head_dim"), std),
            "cross_wk": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim"), std),
            "cross_wv": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim"), std),
            "cross_wo": Spec((h, hd, d), ("q_heads", "head_dim", "embed"), std),
        })
    return p


# ---------------------------------------------------------------------------
# Core scaled-dot-product attention (shared by all modes)
# ---------------------------------------------------------------------------

def _expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each KV head."""
    kvh = k.shape[-2]
    if kvh == num_heads:
        return k
    return jnp.repeat(k, num_heads // kvh, axis=-2)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: int, k_valid: Optional[jax.Array]) -> jax.Array:
    """Additive fp32 bias (..., Sq, Sk) built from position tensors."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        ok = ok & (kp <= qp)
    if window > 0:
        ok = ok & (kp > qp - window)
    if k_valid is not None:
        ok = ok & k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
         causal: bool, window: int = 0,
         q_positions: Optional[jax.Array] = None,
         k_positions: Optional[jax.Array] = None,
         k_valid: Optional[jax.Array] = None,
         impl: str = "naive", chunk: int = 1024) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) -> (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    if q_positions is None:
        q_positions = jnp.arange(sq)
    if k_positions is None:
        k_positions = jnp.arange(sk)
    scale = hd ** -0.5

    if (impl == "banded" and causal and window > 0 and k_valid is None
            and sq == sk and sq % chunk == 0 and sq > chunk
            and window <= chunk):
        # opt-in (see EXPERIMENTS §Perf): 2.4x lower compute/memory TERMS on
        # gemma3 train but +12 GiB adjusted peak from band-tile residency
        # under remat -- the fused Pallas flash kernel (window tiles skipped
        # via _tile_live) is the form that gets the FLOP win without the
        # residency cost on real TPUs.
        return _banded_sdpa(q, k, v, window=window, chunk=chunk, scale=scale)

    if impl == "chunked" and sq > chunk and sq % chunk == 0:
        # (indivisible short sequences -- e.g. whisper's 1500-frame
        # encoder -- fall through to the naive path)
        return _chunked_sdpa(q, k, v, causal=causal, window=window,
                             q_positions=q_positions, k_positions=k_positions,
                             k_valid=k_valid, chunk=chunk, scale=scale)

    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    bias = _mask_bias(q_positions, k_positions, causal, window, k_valid)
    scores = scores + bias[..., None, :, :] if bias.ndim == 2 else scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _banded_sdpa(q, k, v, *, window, chunk, scale):
    """Causal sliding-window attention over uniform key bands.

    For query chunk starting at q0, only keys [q0 - window, q0 + chunk)
    can be unmasked.  K/V are left-padded by `window` so every band has
    uniform width (chunk + window) at stride chunk, letting a remat'd
    lax.scan stream one band at a time: score FLOPs/bytes drop from
    O(S^2) to O(S * (chunk + window)) and only one band tile is resident.
    Requires window <= chunk (gemma3: 1024 <= 1024)."""
    b, s, h, hd = q.shape
    n = s // chunk
    kw = chunk + window
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    qc = q.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    def body(_, inp):
        i, qi = inp
        q0 = i * chunk
        ki = jax.lax.dynamic_slice_in_dim(kp, q0, kw, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(vp, q0, kw, axis=1)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qi, ki).astype(jnp.float32)
        scores = scores * scale
        qpos = q0 + jnp.arange(chunk)[:, None]
        kpos = q0 + jnp.arange(kw)[None, :] - window   # absolute key pos
        ok = (kpos >= 0) & (kpos <= qpos) & (kpos > qpos - window)
        scores = jnp.where(ok, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(qi.dtype)
        return None, jnp.einsum("bhqk,bkhd->bqhd", probs, vi)

    _, out = jax.lax.scan(jax.remat(body), None,
                          (jnp.arange(n), qc))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def _chunked_sdpa(q, k, v, *, causal, window, q_positions, k_positions,
                  k_valid, chunk, scale):
    """Online-softmax over query chunks; O(Sq/chunk) scan with remat body.

    Memory: O(B * H * chunk * Sk) score tile per iteration instead of the
    full (Sq x Sk).  This is the flash-attention recurrence and serves as
    the jnp oracle for the Pallas kernel.
    """
    b, sq, h, hd = q.shape
    nq = sq // chunk
    assert sq % chunk == 0, (sq, chunk)
    qc = q.reshape(b, nq, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(nq, chunk)

    def body(_, inputs):
        qi, qpi = inputs
        scores = jnp.einsum("bqhd,bkhd->bhqk", qi, k).astype(jnp.float32)
        scores = scores * scale
        bias = _mask_bias(qpi, k_positions, causal, window, k_valid)
        scores = scores + bias
        m = jnp.max(scores, axis=-1, keepdims=True)
        m = jnp.maximum(m, NEG_INF)  # guard fully-masked rows
        p = jnp.exp(scores - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(qi.dtype), v)
        o = o / jnp.maximum(l, 1e-30).transpose(0, 2, 1, 3).astype(o.dtype)
        return None, o

    _, out = jax.lax.scan(jax.remat(body), None, (qc, qp))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# Self attention block application (train / prefill / decode)
# ---------------------------------------------------------------------------

def project_qkv(p: Params, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array, prefix: str = "") -> Tuple[jax.Array, ...]:
    q = jnp.einsum("bsd,dnh->bsnh", x, p[prefix + "wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p[prefix + "wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p[prefix + "wv"])
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(p: Params, o: jax.Array, prefix: str = "") -> jax.Array:
    return jnp.einsum("bsnh,nhd->bsd", o, p[prefix + "wo"])


def self_attention_train(p: Params, x: jax.Array, cfg: ModelConfig, *,
                         causal: bool = True, window: int = 0,
                         impl: str = "naive", chunk: int = 1024,
                         positions: Optional[jax.Array] = None,
                         prefix: str = "") -> jax.Array:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = project_qkv(p, x, cfg, positions, prefix)
    o = sdpa(q, k, v, causal=causal, window=window, impl=impl, chunk=chunk,
             q_positions=positions, k_positions=positions)
    return attn_out(p, o, prefix)


# ---------------------------------------------------------------------------
# Sequence-sharded decode ("flash-decode" adaptation)
#
# When KV heads don't divide the model axis (GQA kv=8 on a 16-wide axis) the
# materializer shards the KV cache along the *sequence* dim instead.  Two
# SPMD hazards must be avoided: (a) dynamic_update_slice into a sharded dim
# makes the partitioner gather the whole cache; (b) jnp.repeat-style GQA
# expansion reshapes the sharded operand.  ``seqshard_cache_update`` does a
# local, comm-free single-row write under shard_map, and the decode SDPA
# below keeps KV in (S, KV, hd) form, contracting with grouped queries so
# the only collectives are the tiny partial-softmax combines.
# ---------------------------------------------------------------------------

def seqshard_cache_update(cache: jax.Array, new: jax.Array, slot: jax.Array,
                          mesh, seq_axes: Tuple[str, ...],
                          batch_axes: Tuple[str, ...]) -> jax.Array:
    """Write one token row into a sequence-sharded KV cache, locally.

    cache: (B, KV, S, hd) sharded on S over ``seq_axes``; new: (B, KV, 1,
    hd); slot: scalar global row.  Only the owning shard writes."""
    from jax.sharding import PartitionSpec as P

    bspec = (batch_axes if len(batch_axes) > 1 else
             (batch_axes[0] if batch_axes else None))
    sspec = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    cache_spec = P(bspec, None, sspec, None)
    new_spec = P(bspec, None, None, None)

    def local(cache_l, new_l, slot_):
        s_loc = cache_l.shape[2]
        lin = jnp.zeros((), jnp.int32)
        for ax in seq_axes:
            lin = lin * mesh.shape[ax] + jax.lax.axis_index(ax)
        off = lin * s_loc
        loc = jnp.clip(slot_ - off, 0, s_loc - 1)
        in_range = (slot_ >= off) & (slot_ < off + s_loc)
        cur = jax.lax.dynamic_slice_in_dim(cache_l, loc, 1, 2)
        val = jnp.where(in_range, new_l.astype(cache_l.dtype), cur)
        return jax.lax.dynamic_update_slice_in_dim(cache_l, val, loc, 2)

    return shard_map(
        local, mesh=mesh,
        in_specs=(cache_spec, new_spec, P()),
        out_specs=cache_spec)(cache, new, slot)


def gqa_decode_sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
                    k_valid: jax.Array) -> jax.Array:
    """Decode attention without expanding KV heads (seq-shard friendly).

    Layout note: the cache is stored (B, KV, S, hd) -- contraction dims are
    minor-most, so XLA needs no (hoistable, cache-sized) transposes inside
    the per-layer scan (measured: 0.35 GiB/layer of hoisted transpose
    buffers with the (B, S, KV, hd) layout on command-r decode_32k).

    q: (B, 1, H, hd); k, v: (B, KV, S, hd); k_valid: (S,) bool.
    Returns (B, 1, H, hd)."""
    b, one, h, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, one, kv, g, hd)
    scores = jnp.einsum("bqkgh,bksh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(k_valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bqkgh", probs.astype(q.dtype), v)
    return out.reshape(b, one, h, hd)


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  window: int = 0, dtype=jnp.bfloat16):
    """One layer's KV cache struct, laid out (B, KV, S, hd) (see
    gqa_decode_sdpa layout note).  Ring buffer when window > 0."""
    s = min(cache_len, window) if window > 0 else cache_len
    shape = (batch, cfg.num_kv_heads, s, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(cfg: ModelConfig, batch: int, cache_len: int,
                   window: int = 0, dtype=jnp.bfloat16):
    s = min(cache_len, window) if window > 0 else cache_len
    shape = (batch, cfg.num_kv_heads, s, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def self_attention_decode(p: Params, x: jax.Array, cache: Params,
                          pos: jax.Array, cfg: ModelConfig, *,
                          window: int = 0, prefix: str = "",
                          shard_ctx=None) -> Tuple[jax.Array, Params]:
    """One-token decode.  x: (B, 1, D); cache k/v: (B, S, KV, hd);
    pos: scalar current position.  Returns (out, new_cache).

    ``shard_ctx``: optional (mesh, seq_axes, batch_axes) when the cache is
    sequence-sharded (flash-decode materialization)."""
    s_cache = cache["k"].shape[2]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = project_qkv(p, x, cfg, positions, prefix)
    kt = k.transpose(0, 2, 1, 3)                    # (B, KV, 1, hd)
    vt = v.transpose(0, 2, 1, 3)

    slot = jnp.where(window > 0, pos % jnp.maximum(s_cache, 1), pos)
    if shard_ctx is not None:
        mesh, seq_axes, batch_axes = shard_ctx
        new_k = seqshard_cache_update(cache["k"], kt, slot, mesh, seq_axes,
                                      batch_axes)
        new_v = seqshard_cache_update(cache["v"], vt, slot, mesh, seq_axes,
                                      batch_axes)
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], kt, slot,
                                                    axis=2)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], vt, slot,
                                                    axis=2)

    if window > 0:
        # ring buffer: slot i holds the largest absolute position p <= pos
        # with p % s_cache == i (i.e. the most recent write to that slot)
        idx = jnp.arange(s_cache)
        abs_pos = pos - ((pos - idx) % s_cache)
        k_valid = (abs_pos >= 0) & (abs_pos > pos - jnp.minimum(window, s_cache))
    else:
        idx = jnp.arange(s_cache)
        k_valid = idx <= pos

    o = gqa_decode_sdpa(q, new_k, new_v, k_valid)
    return attn_out(p, o, prefix), {"k": new_k, "v": new_v}


def self_attention_prefill(p: Params, x: jax.Array, cfg: ModelConfig, *,
                           window: int = 0, impl: str = "chunked",
                           chunk: int = 1024, cache_len: Optional[int] = None,
                           prefix: str = "") -> Tuple[jax.Array, Params]:
    """Full forward + returns populated KV cache (ring-sliced for SWA)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = project_qkv(p, x, cfg, positions, prefix)
    o = sdpa(q, k, v, causal=True, window=window, impl=impl, chunk=chunk,
             q_positions=positions, k_positions=positions)
    if window > 0 and s > window:
        # keep the last `window` entries arranged by (abs_pos % window)
        tail_k, tail_v = k[:, -window:], v[:, -window:]
        shift = s % window
        cache = {"k": jnp.roll(tail_k, shift, axis=1),
                 "v": jnp.roll(tail_v, shift, axis=1)}
    else:
        cache = {"k": k, "v": v}
    cache = {n: a.transpose(0, 2, 1, 3) for n, a in cache.items()}
    return attn_out(p, o, prefix), cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attention(p: Params, x: jax.Array, enc_kv: Params,
                    cfg: ModelConfig) -> jax.Array:
    q = jnp.einsum("bsd,dnh->bsnh", x, p["cross_wq"])
    o = sdpa(q, enc_kv["k"], enc_kv["v"], causal=False, impl="naive")
    return attn_out(p, o, prefix="cross_")


def encode_cross_kv(p: Params, enc_out: jax.Array) -> Params:
    return {"k": jnp.einsum("btd,dnh->btnh", enc_out, p["cross_wk"]),
            "v": jnp.einsum("btd,dnh->btnh", enc_out, p["cross_wv"])}
