"""Mixture-of-Experts FFN with shard_map-local capacity dispatch.

Expert-parallel design (TPU-native adaptation of the paper's remote data
components): routed expert weights are *data components* sharded over the
``model`` axis (expert parallelism); shared experts are *local* components.
This mirrors the paper's two compiled versions -- a local-access path
(shared experts: plain einsums, no comm) and a remote-access path (routed
experts: explicit collective exchange).

SPMD hazard note: a global sort/scatter dispatch makes the XLA partitioner
replicate the token stream (measured: 440 GiB/device on dbrx train_4k).
The dispatch here is therefore *local by construction* under shard_map:

  * tokens stay sharded over the batch axes; routing, top-k, sort and the
    capacity scatter are all shard-local (T_loc tokens);
  * each model-axis shard computes its E_loc experts on the locally built
    (E, C_loc, D) buffer slice;
  * one psum over the model axis combines expert outputs -- the single
    explicit "remote access" per MoE layer (hillclimb target: all-to-all).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.layers import Spec, gated_mlp, gated_mlp_specs

Params = Dict[str, Any]

NEG = -1e30


def padded_num_experts(num_experts: int, multiple: int = 16) -> int:
    """Experts padded so the expert axis shards over the model axis."""
    return ((num_experts + multiple - 1) // multiple) * multiple


def moe_specs(cfg: ModelConfig) -> Params:
    m = cfg.moe
    d = cfg.d_model
    e = padded_num_experts(m.num_experts)
    p: Params = {
        "router": Spec((d, e), ("embed", "experts"), std=0.02),
        "we_gate": Spec((e, d, m.d_expert), ("experts", "embed", "expert_ffn")),
        "we_up": Spec((e, d, m.d_expert), ("experts", "embed", "expert_ffn")),
        "we_down": Spec((e, m.d_expert, d), ("experts", "expert_ffn", "embed")),
    }
    if m.num_shared_experts > 0:
        p["shared"] = gated_mlp_specs(d, m.d_shared_expert)
        p["shared_gate"] = Spec((d, 1), ("embed", None), std=0.02)
    return p


def _capacity(tokens: int, num_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = int(tokens * top_k * capacity_factor / num_experts)
    return max(8, (c + 7) // 8 * 8)


def route(p_router: jax.Array, x: jax.Array, cfg: ModelConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router on (T, D) tokens: (weights (T,k), ids (T,k), aux_loss)."""
    m = cfg.moe
    e_pad = p_router.shape[-1]
    logits = jnp.einsum("td,de->te", x, p_router).astype(jnp.float32)
    if e_pad > m.num_experts:
        pad_mask = jnp.arange(e_pad) >= m.num_experts
        logits = jnp.where(pad_mask, NEG, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, m.top_k)              # (T, k)
    weights = weights / jnp.sum(weights, -1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e_pad, dtype=jnp.float32), axis=1), axis=0)
    aux = jnp.sum(me * ce) * float(m.num_experts)
    return weights.astype(x.dtype), ids, aux


def _local_expert_ffn(x: jax.Array, p: Params, cfg: ModelConfig,
                      e_index: jax.Array, e_total: int) -> Tuple[jax.Array, jax.Array]:
    """Shard-local routed-expert computation on (T_loc, D) tokens.

    p['we_*'] are the LOCAL expert slices (E_loc, ...).  Returns the local
    partial output (T_loc, D) -- caller psums over the model axis -- and the
    shard-local aux loss."""
    m = cfg.moe
    t, d = x.shape
    k = m.top_k
    e_loc = p["we_gate"].shape[0]
    cap = _capacity(t, e_total, k, m.capacity_factor)

    weights, ids, aux = route(p["router"], x, cfg)

    flat_ids = ids.reshape(-1)
    flat_w = weights.reshape(-1)
    token_of = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(e_total), side="left")
    pos_sorted = jnp.arange(t * k) - seg_start[sorted_ids]
    pos_in_expert = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)

    keep = pos_in_expert < cap
    # this shard owns experts [e0, e0 + e_loc)
    e0 = e_index * e_loc
    local_id = flat_ids - e0
    mine = keep & (local_id >= 0) & (local_id < e_loc)
    slot = jnp.where(mine, local_id * cap + pos_in_expert, e_loc * cap)

    buf = jnp.zeros((e_loc * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(x[token_of], mode="drop")
    ebuf = buf[: e_loc * cap].reshape(e_loc, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, p["we_gate"])) \
        * jnp.einsum("ecd,edf->ecf", ebuf, p["we_up"])
    out = jnp.einsum("ecf,efd->ecd", h, p["we_down"])
    out = out.reshape(e_loc * cap, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), x.dtype)], axis=0)

    gathered = out[slot] * flat_w[:, None].astype(out.dtype)
    y = jax.ops.segment_sum(gathered, token_of, num_segments=t)
    return y.astype(x.dtype), aux


def _a2a_expert_ffn(x: jax.Array, p: Params, cfg: ModelConfig,
                    model_axis: str, e_total: int, n_shards: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """All-to-all EP on tokens already sharded over the model axis.

    x: (T_loc, D) -- this shard's token slice.  Routing/top-k/capacity
    run locally; tokens travel to their expert's owner shard via
    all_to_all (payload ~ k*cf*T_loc*D / n_shards per hop, vs the psum
    combine's full T_loc*D), compute runs on the owner, and a second
    all_to_all returns results.  Beyond-paper optimization (§Perf)."""
    m = cfg.moe
    t, d = x.shape
    k = m.top_k
    e_loc = e_total // n_shards
    # capacity per (destination shard, local expert), sized on local tokens
    cap = _capacity(t, e_total, k, m.capacity_factor)

    weights, ids, aux = route(p["router"], x, cfg)
    flat_ids = ids.reshape(-1)
    flat_w = weights.reshape(-1)
    token_of = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(e_total), side="left")
    pos_sorted = jnp.arange(t * k) - seg_start[sorted_ids]
    pos_in_expert = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    keep = pos_in_expert < cap
    slot = jnp.where(keep, flat_ids * cap + pos_in_expert, e_total * cap)

    buf = jnp.zeros((e_total * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(x[token_of], mode="drop")
    send = buf[: e_total * cap].reshape(n_shards, e_loc * cap, d)
    # exchange: shard j receives every shard's slice for ITS experts
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)          # (n_shards, e_loc*cap, d)
    ebuf = recv.reshape(n_shards, e_loc, cap, d).transpose(1, 0, 2, 3) \
        .reshape(e_loc, n_shards * cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, p["we_gate"])) \
        * jnp.einsum("ecd,edf->ecf", ebuf, p["we_up"])
    out = jnp.einsum("ecf,efd->ecd", h, p["we_down"])

    # return trip
    back = out.reshape(e_loc, n_shards, cap, d).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, model_axis, split_axis=0, concat_axis=0,
                             tiled=False)            # (n_shards, e_loc, cap, d)
    out_full = ret.reshape(e_total * cap, d)
    out_full = jnp.concatenate([out_full, jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = out_full[slot] * flat_w[:, None].astype(out_full.dtype)
    y = jax.ops.segment_sum(gathered, token_of, num_segments=t)
    return y.astype(x.dtype), aux


def moe_block(p: Params, x: jax.Array, cfg: ModelConfig,
              shard_ctx=None, dispatch: str = "psum"
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux).

    shard_ctx: optional (mesh, model_axis, batch_axes) enabling the
    expert-parallel shard_map path; None runs the single-shard reference
    (still exact: e_index=0, e_total=E).  dispatch: 'psum' | 'a2a'."""
    from jax.sharding import PartitionSpec as P
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    m = cfg.moe
    e_pad = padded_num_experts(m.num_experts)

    if shard_ctx is None:
        y, aux = _local_expert_ffn(
            flat, {k: p[k] for k in ("router", "we_gate", "we_up", "we_down")},
            cfg, jnp.zeros((), jnp.int32), e_pad)
    elif dispatch == "a2a":
        mesh, model_axis, batch_axes = shard_ctx
        n_shards = mesh.shape[model_axis]
        tok_spec = tuple(batch_axes) + (model_axis,)

        def local(xl, router, wg, wu, wd):
            yl, auxl = _a2a_expert_ffn(
                xl, {"router": router, "we_gate": wg, "we_up": wu,
                     "we_down": wd}, cfg, model_axis, e_pad, n_shards)
            auxl = jax.lax.pmean(auxl, tuple(mesh.axis_names))
            return yl, auxl

        y, aux = shard_map(
            local, mesh=mesh,
            in_specs=(P(tok_spec, None), P(None, None),
                      P(model_axis, None, None), P(model_axis, None, None),
                      P(model_axis, None, None)),
            out_specs=(P(tok_spec, None), P()))(flat, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    else:
        mesh, model_axis, batch_axes = shard_ctx
        bspec = (batch_axes if len(batch_axes) > 1 else
                 (batch_axes[0] if batch_axes else None))

        def local(xl, router, wg, wu, wd):
            e_idx = jax.lax.axis_index(model_axis)
            yl, auxl = _local_expert_ffn(
                xl, {"router": router, "we_gate": wg, "we_up": wu,
                     "we_down": wd}, cfg, e_idx, e_pad)
            yl = jax.lax.psum(yl, model_axis)
            auxl = jax.lax.pmean(auxl, tuple(mesh.axis_names))
            return yl, auxl

        y, aux = shard_map(
            local, mesh=mesh,
            in_specs=(P(bspec, None), P(None, None),
                      P(model_axis, None, None), P(model_axis, None, None),
                      P(model_axis, None, None)),
            out_specs=(P(bspec, None), P()))(flat, p["router"], p["we_gate"], p["we_up"], p["we_down"])

    if m.num_shared_experts > 0:
        gate = jax.nn.sigmoid(
            jnp.einsum("td,dz->tz", flat, p["shared_gate"]).astype(jnp.float32))
        y = y + (gate.astype(flat.dtype) * gated_mlp(p["shared"], flat))
    return y.reshape(b, s, d), aux
