"""Shared building blocks: norms, rotary embeddings, MLPs, embeddings.

Pure-function style: every layer is ``f(params, x, ...) -> y`` over plain
pytrees.  Parameter *specs* (shape/dtype/logical axes) live next to the
``init``/``apply`` pair so that the resource-graph profiles and the sharding
planner share one source of truth with the compute code.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Param-spec helper: a leaf spec is (shape, logical_axes, init_scale)
# ---------------------------------------------------------------------------


class Spec:
    """Parameter leaf spec: shape + logical axis names + init std."""

    __slots__ = ("shape", "axes", "std")

    def __init__(self, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                 std: float = 0.02):
        assert len(shape) == len(axes), (shape, axes)
        self.shape = tuple(shape)
        self.axes = tuple(axes)
        self.std = std

    def __repr__(self):
        return f"Spec{self.shape}{self.axes}"


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def init_from_specs(rng: jax.Array, specs, dtype=jnp.bfloat16):
    """Materialize a params pytree from a spec pytree."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, spec in zip(keys, leaves):
        if spec.std == 0.0:  # zeros (biases, some gates)
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.std == 1.0 and len(spec.shape) <= 2 and (
                len(spec.shape) == 1 or spec.shape[-1] == spec.shape[0]):
            # norm gains default to ones
            out.append(jnp.ones(spec.shape, dtype))
        else:
            out.append((jax.random.normal(key, spec.shape, jnp.float32)
                        * spec.std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def shape_structs(specs, dtype=jnp.bfloat16):
    """Spec tree -> ShapeDtypeStruct tree (for dry-run lowering)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
        is_leaf=is_spec)


def logical_axes(specs):
    """Spec tree -> logical-axes tree (tuples of axis names)."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_bytes(specs, bytes_per_param: int = 2) -> int:
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=is_spec):
        total += int(np.prod(s.shape)) * bytes_per_param
    return total


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gain.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, gain: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gain.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def group_norm_heads(x: jax.Array, gain: jax.Array, eps: float = 64e-5):
    """Per-head group norm over the last dim of (..., H, hd) (rwkv6 style)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gain.astype(jnp.float32)).astype(x.dtype)


def rms_norm_spec(d: int) -> Spec:
    return Spec((d,), ("embed",), std=0.0)  # zero-init: (1+g) parameterization


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(0, d_model, 2)[None, :]
    ang = pos / np.power(10_000.0, dim / d_model)
    out = np.zeros((seq_len, d_model), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def gated_mlp_specs(d_model: int, d_ff: int) -> Params:
    return {
        "wi_gate": Spec((d_model, d_ff), ("embed", "ffn")),
        "wi_up": Spec((d_model, d_ff), ("embed", "ffn")),
        "wo": Spec((d_ff, d_model), ("ffn", "embed")),
    }


def gated_mlp(p: Params, x: jax.Array, act=jax.nn.silu) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
    u = jnp.einsum("...d,df->...f", x, p["wi_up"])
    return jnp.einsum("...f,fd->...d", act(g) * u, p["wo"])


def mlp_specs(d_model: int, d_ff: int) -> Params:
    return {
        "wi": Spec((d_model, d_ff), ("embed", "ffn")),
        "bi": Spec((d_ff,), ("ffn",), std=0.0),
        "wo": Spec((d_ff, d_model), ("ffn", "embed")),
        "bo": Spec((d_model,), ("embed",), std=0.0),
    }


def mlp(p: Params, x: jax.Array, act=jax.nn.gelu) -> jax.Array:
    h = act(jnp.einsum("...d,df->...f", x, p["wi"]) + p["bi"])
    return jnp.einsum("...f,fd->...d", h, p["wo"]) + p["bo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_specs(vocab: int, d_model: int, tie: bool) -> Params:
    out = {"tok": Spec((vocab, d_model), ("vocab", "embed"))}
    if not tie:
        out["head"] = Spec((d_model, vocab), ("embed", "vocab"))
    return out


def embed(p: Params, tokens: jax.Array, scale: float = 1.0) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    if scale != 1.0:
        x = (x.astype(jnp.float32) * scale).astype(x.dtype)
    return x


def unembed(p: Params, x: jax.Array, softcap: float = 0.0) -> jax.Array:
    if "head" in p:
        logits = jnp.einsum("...d,dv->...v", x, p["head"])
    else:
        logits = jnp.einsum("...d,vd->...v", x, p["tok"])
    logits = logits.astype(jnp.float32)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over valid positions.  logits fp32 (..., V); labels (...).

    SPMD note: the label log-prob is extracted with a one-hot contraction,
    NOT take_along_axis -- a vocab-dim gather on vocab-sharded logits makes
    the partitioner replicate the full logits per device (measured
    ~290 GiB/device on command-r train_4k); the contraction partitions
    cleanly into a partial sum + tiny all-reduce."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.einsum("...v,...v->...", logits, onehot)
    nll = lse - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
