"""Model facade: param specs, init, and the three entry points
(train loss / prefill / decode) for every assigned architecture.

All three entry points run the same pattern-block code; the stack is a
``lax.scan`` over pattern blocks by default (keeps HLO size ~O(1) in depth)
or unrolled for cost-extrapolation probes.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.transformer import ImplConfig

Params = Dict[str, Any]

MOE_AUX_WEIGHT = 0.01


class Model:
    """Stateless model: pure functions over a params pytree."""

    def __init__(self, cfg: ModelConfig, impl: Optional[ImplConfig] = None):
        self.cfg = cfg
        self.impl = impl or ImplConfig()

    # -- parameters --------------------------------------------------------
    def param_specs(self) -> Params:
        specs = T.model_specs(self.cfg)
        nb = self._num_blocks()
        if nb != self.cfg.num_blocks:
            specs["blocks"] = jax.tree.map(
                lambda s: L.Spec((nb,) + s.shape[1:], s.axes, s.std),
                specs["blocks"], is_leaf=L.is_spec)
        return specs

    def param_structs(self) -> Params:
        return L.shape_structs(self.param_specs())

    def logical_axes(self) -> Params:
        return L.logical_axes(self.param_specs())

    def init_params(self, rng: jax.Array) -> Params:
        return L.init_from_specs(rng, self.param_specs())

    def _num_blocks(self) -> int:
        if self.impl.num_blocks_override is not None:
            return self.impl.num_blocks_override
        return self.cfg.num_blocks

    # -- embedding / head --------------------------------------------------
    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        scale = math.sqrt(self.cfg.d_model) if self.cfg.name.startswith(
            "gemma") else 1.0
        x = L.embed(params["embed"], tokens, scale)
        if self.cfg.rope_theta <= 0 and not self.cfg.is_encdec:
            pass
        return x

    def _add_positional(self, x: jax.Array, offset: int = 0) -> jax.Array:
        """Sinusoidal positions for non-RoPE models (whisper stub)."""
        if self.cfg.rope_theta > 0:
            return x
        pos = L.sinusoidal_positions(x.shape[1] + offset,
                                     self.cfg.d_model)[offset:]
        return (x.astype(jnp.float32) + pos).astype(x.dtype)

    # -- frontends (stubs per assignment) -----------------------------------
    def _encoder(self, params: Params, enc_feats: jax.Array) -> jax.Array:
        """Whisper encoder over precomputed frame embeddings (conv stub)."""
        cfg = self.cfg
        enc = params["encoder"]
        x = self._add_positional(enc_feats)

        def body(x, bp):
            h = T.apply_norm(cfg, bp["ln1"], x)
            q = jnp.einsum("bsd,dnh->bsnh", h, bp["attn"]["wq"])
            k = jnp.einsum("bsd,dnh->bsnh", h, bp["attn"]["wk"])
            v = jnp.einsum("bsd,dnh->bsnh", h, bp["attn"]["wv"])
            o = attn.sdpa(q, k, v, causal=False, impl=self.impl.attn_impl,
                          chunk=self.impl.attn_chunk)
            x = x + attn.attn_out(bp["attn"], o)
            h = T.apply_norm(cfg, bp["ln2"], x)
            x = x + L.mlp(bp["mlp"], h)
            return x, None

        x, _ = jax.lax.scan(T._remat(body, self.impl.remat), x, enc["blocks"])
        return T.apply_norm(cfg, enc["ln_f"], x)

    def _vlm_prefix(self, params: Params, img_feats: jax.Array) -> jax.Array:
        """Project stubbed CLIP patch embeddings into the LM stream."""
        return jnp.einsum("bnc,cd->bnd", img_feats, params["img_proj"])

    # -- stack runners -------------------------------------------------------
    def _run_blocks_train(self, params: Params, x: jax.Array,
                          enc_out: Optional[jax.Array]
                          ) -> Tuple[jax.Array, jax.Array]:
        cfg, impl = self.cfg, self.impl
        shared = {k: params[k] for k in ("shared_attn",) if k in params}

        def block_body(carry, bp):
            x, aux = carry
            for i, kind in enumerate(cfg.pattern):
                x, a = T.apply_block_train(cfg, impl, kind,
                                           bp[f"p{i}_{kind}"], x, shared,
                                           enc_out)
                aux = aux + a
            return (x, aux), None

        aux0 = jnp.zeros((), jnp.float32)
        if impl.unroll_blocks or not impl.scan_blocks:
            carry = (x, aux0)
            for i in range(self._num_blocks()):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                carry, _ = block_body(carry, bp)
            x, aux = carry
        else:
            (x, aux), _ = jax.lax.scan(
                T._remat(block_body, impl.remat), (x, aux0), params["blocks"])
        return x, aux

    # -- entry point: training loss -----------------------------------------
    def loss_fn(self, params: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        mask = batch.get("mask")
        x = self._embed(params, tokens)
        n_img = 0
        if cfg.family == "vlm" and "img_feats" in batch:
            prefix = self._vlm_prefix(params, batch["img_feats"])
            x = jnp.concatenate([prefix, x], axis=1)
            n_img = prefix.shape[1]
        x = self._add_positional(x)
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._encoder(params, batch["enc_feats"])
        x, aux = self._run_blocks_train(params, x, enc_out)
        x = T.apply_norm(cfg, params["ln_f"], x)
        if n_img:
            x = x[:, n_img:]
        ce = self._cross_entropy(params, x, labels, mask)
        loss = ce + MOE_AUX_WEIGHT * aux
        return loss, {"ce": ce, "aux": aux}

    def _cross_entropy(self, params, x, labels, mask):
        """CE over the vocab head.  With impl.loss_chunk > 0 the unembed +
        softmax stream over sequence chunks under remat, so the fp32
        logits (B, S, V) -- the single largest train-step temporary for
        large-vocab archs -- never materialize at once (beyond-paper
        optimization; see EXPERIMENTS.md §Perf)."""
        cfg = self.cfg
        c = self.impl.loss_chunk
        if c <= 0 or x.shape[1] <= c or x.shape[1] % c != 0:
            logits = L.unembed(params["embed"], x, cfg.logit_softcap)
            return L.softmax_cross_entropy(logits, labels, mask)
        b, s, d = x.shape
        n = s // c
        xc = x.reshape(b, n, c, d).transpose(1, 0, 2, 3)
        lc = labels.reshape(b, n, c).transpose(1, 0, 2)
        mc = (mask.reshape(b, n, c).transpose(1, 0, 2)
              if mask is not None else jnp.ones((n, b, c), jnp.float32))

        def body(carry, inp):
            xi, li, mi = inp
            logits = L.unembed(params["embed"], xi, cfg.logit_softcap)
            lse = jax.nn.logsumexp(logits, axis=-1)
            onehot = jax.nn.one_hot(li, logits.shape[-1],
                                    dtype=logits.dtype)
            ll = jnp.einsum("...v,...v->...", logits, onehot)
            nll = (lse - ll) * mi.astype(jnp.float32)
            tot, cnt = carry
            return (tot + nll.sum(), cnt + mi.astype(jnp.float32).sum()), None

        (tot, cnt), _ = jax.lax.scan(
            jax.remat(body), (jnp.zeros((), jnp.float32),
                              jnp.zeros((), jnp.float32)), (xc, lc, mc))
        return tot / jnp.maximum(cnt, 1.0)

    # -- entry point: prefill ------------------------------------------------
    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                cache_len: int) -> Tuple[jax.Array, Params]:
        """Full forward over the prompt; returns (last-token logits, cache)."""
        cfg, impl = self.cfg, self.impl
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        if cfg.family == "vlm" and "img_feats" in batch:
            prefix = self._vlm_prefix(params, batch["img_feats"])
            x = jnp.concatenate([prefix, x], axis=1)
        x = self._add_positional(x)
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._encoder(params, batch["enc_feats"])
        shared = {k: params[k] for k in ("shared_attn",) if k in params}

        def block_body(x, bp):
            caches = {}
            for i, kind in enumerate(cfg.pattern):
                x, c = T.apply_block_prefill(cfg, impl, kind,
                                             bp[f"p{i}_{kind}"], x, shared,
                                             enc_out, cache_len)
                caches[f"p{i}_{kind}"] = c
            return x, caches

        if impl.unroll_blocks or not impl.scan_blocks:
            xs, stacked = x, []
            for i in range(self._num_blocks()):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                xs, c = block_body(xs, bp)
                stacked.append(c)
            cache = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
            x = xs
        else:
            # cache lives in the scan CARRY and is written per-layer with
            # dynamic_update_slice: in-place aliasing inside the while body
            # (the xs/ys pattern double-buffers the whole stacked cache --
            # 2x full-cache copies measured in XLA buffer assignment).
            cache0 = self.init_cache(tokens.shape[0], cache_len)

            def carry_body(carry, inp):
                x, cache = carry
                i, bp = inp
                x, c = block_body(x, bp)
                cache = jax.tree.map(
                    lambda full, s: jax.lax.dynamic_update_index_in_dim(
                        full, s.astype(full.dtype), i, 0), cache, c)
                return (x, cache), None

            nb = self._num_blocks()
            (x, cache), _ = jax.lax.scan(
                T._remat(carry_body, impl.remat), (x, cache0),
                (jnp.arange(nb), params["blocks"]))
        x = T.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(params["embed"], x[:, -1:], cfg.logit_softcap)
        return logits, cache

    # -- entry point: decode (one token) -------------------------------------
    def decode_step(self, params: Params, tokens: jax.Array, cache: Params,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        """tokens: (B, 1) -> (logits (B, 1, V), new cache)."""
        cfg, impl = self.cfg, self.impl
        x = self._embed(params, tokens)
        x = self._add_positional_decode(x, pos)
        shared = {k: params[k] for k in ("shared_attn",) if k in params}

        def block_body(x, bp, bc):
            new_c = {}
            for i, kind in enumerate(cfg.pattern):
                key = f"p{i}_{kind}"
                x, c = T.apply_block_decode(cfg, impl, kind, bp[key], x,
                                            bc[key], pos, shared)
                new_c[key] = c
            return x, new_c

        if impl.unroll_blocks or not impl.scan_blocks:
            stacked = []
            for i in range(self._num_blocks()):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                bc = jax.tree.map(lambda a: a[i], cache)
                x, c = block_body(x, bp, bc)
                stacked.append(c)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
        else:
            # cache in the scan carry (see prefill): the per-layer slice is
            # read with dynamic_index and written back in place.
            def carry_body(carry, inp):
                x, cache = carry
                i, bp = inp
                bc = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, i, 0, keepdims=False), cache)
                x, c = block_body(x, bp, bc)
                cache = jax.tree.map(
                    lambda full, s: jax.lax.dynamic_update_index_in_dim(
                        full, s.astype(full.dtype), i, 0), cache, c)
                return (x, cache), None

            nb = self._num_blocks()
            (x, new_cache), _ = jax.lax.scan(
                carry_body, (x, cache), (jnp.arange(nb), params["blocks"]))
        x = T.apply_norm(cfg, params["ln_f"], x)
        logits = L.unembed(params["embed"], x, cfg.logit_softcap)
        return logits, new_cache

    def _add_positional_decode(self, x: jax.Array, pos: jax.Array):
        if self.cfg.rope_theta > 0:
            return x
        d = self.cfg.d_model
        i = jnp.arange(0, d, 2, dtype=jnp.float32)
        inv = jnp.power(10_000.0, -i / d)
        ang = pos.astype(jnp.float32) * inv
        pe = jnp.zeros((d,), jnp.float32)
        pe = pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
        return (x.astype(jnp.float32) + pe).astype(x.dtype)

    # -- cache helpers -------------------------------------------------------
    def cache_specs(self, batch: int, cache_len: int):
        cfg = self.cfg
        nb = self._num_blocks()
        out = {}
        for i, kind in enumerate(cfg.pattern):
            leaf = T.block_cache_specs(cfg, kind, batch, cache_len)
            out[f"p{i}_{kind}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((nb,) + s.shape, s.dtype), leaf)
        return out

    def init_cache(self, batch: int, cache_len: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_specs(batch, cache_len))


def build_model(cfg: ModelConfig, impl: Optional[ImplConfig] = None) -> Model:
    return Model(cfg, impl)
