from repro.models.model import Model, build_model
from repro.models.transformer import ImplConfig

__all__ = ["Model", "build_model", "ImplConfig"]
