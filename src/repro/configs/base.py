"""Configuration system for Zenix.

Zenix (paper text: "BulkX") is a *resource-centric* adaptive execution
framework.  A ``ModelConfig`` describes an architecture ("application" in the
paper's terms); a ``ShapeConfig`` describes one invocation's input shape.  The
pair (arch x shape) is an *invocation class*: the materializer adapts the
physical execution plan per invocation class, exactly as the paper adapts
resource allocation per invocation.

All architecture configs come from public literature; the exact numbers are
pinned by the assignment (see DESIGN.md for sources / verified tiers).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Block kinds: the repeating-pattern units a model is built from.  A model's
# layer stack is ``pattern * repeat`` (+ optional prologue/epilogue).  The
# resource graph has one compute component per pattern entry.
# ---------------------------------------------------------------------------
ATTN_GLOBAL = "attn_global"        # full causal self attention
ATTN_LOCAL = "attn_local"          # sliding-window self attention
ATTN_SHARED = "attn_shared"        # weight-shared attention block (zamba2)
RWKV6 = "rwkv6"                    # RWKV-6 "Finch" time-mix + channel-mix
MAMBA2 = "mamba2"                  # Mamba-2 SSD block
MOE = "moe"                        # MoE FFN block (attention + routed experts)
ENC_ATTN = "enc_attn"              # bidirectional encoder self attention
DEC_ATTN = "dec_attn"              # decoder self attention + cross attention

SUBQUADRATIC_KINDS = {RWKV6, MAMBA2, ATTN_LOCAL}


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden size
    num_shared_experts: int = 0
    d_shared_expert: int = 0           # hidden size of the shared-expert MLP
    capacity_factor: float = 1.25
    router_jitter: float = 0.0

    @property
    def active_experts(self) -> int:
        return self.top_k


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64                # N: per-head SSM state size
    head_dim: int = 64                 # P: channels per SSM head
    expand: int = 2                    # mamba expansion factor
    conv_width: int = 4                # depthwise conv width
    chunk_size: int = 128              # SSD / linear-attn chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default d_model // num_heads
    # Repeating structural pattern. E.g. gemma3: 5x local + 1x global.
    # The full stack is ``pattern`` repeated ``num_layers/len(pattern)`` times
    # (except encdec, where num_layers counts one side).
    pattern: Tuple[str, ...] = (ATTN_GLOBAL,)
    sliding_window: int = 0            # >0 for ATTN_LOCAL entries
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    use_qk_norm: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec only
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0           # frames/patches produced by frontend stub
    # vlm only
    num_image_tokens: int = 0
    # max trained context (informational)
    max_context: int = 131_072
    dtype: str = "bfloat16"
    notes: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {len(self.pattern)}")

    # -- derived quantities used by resource profiles ----------------------
    @property
    def num_blocks(self) -> int:
        """Number of repeating pattern blocks (scan length)."""
        return self.num_layers // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.family in ("encdec", "audio") and self.num_encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return all(k in (RWKV6, MAMBA2) for k in self.pattern)

    @property
    def supports_long_context(self) -> bool:
        """True if decode over >=500k context is sub-quadratic / bounded-KV.

        Pure full-attention architectures are skipped for ``long_500k`` per
        the assignment; SSM / hybrid / mostly-local stacks run it: full-KV
        blocks (global/shared attention, MoE-attn, enc-dec) must be a small
        minority (<= 1/4) of the pattern."""
        full_kv = (ATTN_GLOBAL, ATTN_SHARED, MOE, DEC_ATTN, ENC_ATTN)
        n_full = sum(1 for k in self.pattern if k in full_kv)
        return n_full * 4 <= len(self.pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (whisper is enc-dec)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and profiles)."""
        from repro.core.profiles import model_param_count
        return model_param_count(self)

    def active_param_count(self) -> int:
        from repro.core.profiles import model_active_param_count
        return model_active_param_count(self)

    def scaled(self, **overrides) -> "ModelConfig":
        """Return a reduced copy (smoke tests)."""
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch x shape) is a runnable cell, with a reason if not."""
    if shape.name == "long_500k" and not model.supports_long_context:
        return False, ("pure full-attention stack: 500k-token decode KV is "
                       "not sub-quadratic-bounded; skipped per assignment")
    return True, ""


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import config modules lazily on first miss
        import repro.configs  # noqa: F401  (triggers registration)
        if name not in _REGISTRY:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def all_cells(mesh_names: Sequence[str] = ("single_pod", "multi_pod")):
    """Every runnable (arch x shape x mesh) cell + documented skips."""
    cells, skips = [], []
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                skips.append((arch, sname, why))
                continue
            for mesh in mesh_names:
                cells.append((arch, sname, mesh))
    return cells, skips
