"""tinyllama-1.1b [dense]: 22L, d_model=2048, 32H (GQA kv=4), d_ff=5632,
vocab=32000.  llama2-arch small.  [arXiv:2401.02385; hf]
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL, register


@register("tinyllama-1.1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        family="dense",
        num_layers=22,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=64,
        d_ff=5632,
        vocab_size=32_000,
        pattern=(ATTN_GLOBAL,),
        rope_theta=10_000.0,
        max_context=2048,
    )
