"""phi-3-vision-4.2b [vlm]: 32L, d_model=3072, 32H (GQA kv=32), d_ff=8192,
vocab=32064.  phi3-mini backbone + CLIP vision frontend (STUB: input_specs
provides precomputed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL, register


@register("phi-3-vision-4.2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32_064,
        pattern=(ATTN_GLOBAL,),
        num_image_tokens=576,         # stubbed CLIP patch embeddings
        rope_theta=10_000.0,
        max_context=131_072,
        notes="vision frontend stubbed; image tokens prepended to sequence",
    )
