"""Architecture config registry.  Importing this package registers all
assigned architectures."""

from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig, ShapeConfig,
                                SHAPES, get_config, list_archs, all_cells,
                                shape_applicable)

# one module per assigned architecture
from repro.configs import whisper_base      # noqa: F401
from repro.configs import rwkv6_7b          # noqa: F401
from repro.configs import gemma3_12b        # noqa: F401
from repro.configs import command_r_35b     # noqa: F401
from repro.configs import mistral_nemo_12b  # noqa: F401
from repro.configs import tinyllama_1_1b    # noqa: F401
from repro.configs import zamba2_2_7b       # noqa: F401
from repro.configs import qwen2_moe_a2_7b   # noqa: F401
from repro.configs import dbrx_132b         # noqa: F401
from repro.configs import phi3_vision_4_2b  # noqa: F401

ALL_ARCHS = list_archs()

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
           "get_config", "list_archs", "all_cells", "shape_applicable",
           "ALL_ARCHS"]
