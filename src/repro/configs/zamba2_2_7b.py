"""zamba2-2.7b [hybrid]: 54L, d_model=2560, 32H (GQA kv=32), d_ff=10240,
ssm_state=64.  Mamba2 backbone + weight-SHARED attention blocks.
[arXiv:2411.15242; hf]

The shared attention block is a single set of weights (one *data component*
in resource-graph terms) applied at multiple depths (many *compute
components*) -- the clearest instance of the paper's "one data component,
many compute components" structure among the assigned archs.
"""
from repro.configs.base import (ModelConfig, SSMConfig, MAMBA2, ATTN_SHARED,
                                register)


@register("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10_240,
        vocab_size=32_000,
        pattern=(MAMBA2,) * 5 + (ATTN_SHARED,),
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=128),
        rope_theta=10_000.0,
        max_context=4096,
        notes="9 pattern blocks of 5 mamba2 + 1 shared-weight attention",
    )
