"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H (kv=8), d_ff=2048,
vocab=51865.  Encoder-decoder; conv audio frontend is a STUB (input_specs
provides precomputed 1500-frame embeddings).  [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig, DEC_ATTN, register


@register("whisper-base")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="audio",
        num_layers=6,                 # decoder layers (assignment: 6L)
        num_encoder_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=51_865,
        pattern=(DEC_ATTN,),
        encoder_seq_len=1_500,        # 30 s audio -> 1500 frames post-conv
        rope_theta=0.0,               # whisper uses learned/sinusoidal pos
        tie_embeddings=True,
        max_context=448,
        notes="enc-dec; conv frontend stubbed as precomputed frame embeddings",
    )
