"""Reduced same-family configs: the CPU smoke-scale reduction recipe.

Every CPU entry point (launchers with ``--reduced``, the runtime's
``Application(..., reduced=True)``, and the test suite) shrinks a
production architecture through this ONE function so they all exercise
the same code path at the same scale.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


def reduced_config(cfg: ModelConfig, **extra) -> ModelConfig:
    """Reduced same-family config for CPU smoke runs."""
    kw = dict(
        num_layers=len(cfg.pattern),
        d_model=64,
        num_heads=4,
        num_kv_heads=(max(1, min(cfg.num_kv_heads, 4))
                      if cfg.num_kv_heads < cfg.num_heads else 4),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        encoder_seq_len=16 if cfg.is_encdec else 0,
        num_encoder_layers=2 if cfg.is_encdec else 0,
        num_image_tokens=8 if cfg.family == "vlm" else 0,
        max_context=1 << 30,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_expert=32,
            d_shared_expert=64 if cfg.moe.num_shared_experts else 0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=8, head_dim=8,
                                        chunk_size=4)
    kw.update(extra)
    return cfg.scaled(**kw)
