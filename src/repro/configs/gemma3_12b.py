"""gemma3-12b [dense]: 48L, d_model=3840, 16H (GQA kv=8), d_ff=15360,
vocab=262144.  5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL, ATTN_LOCAL, register


@register("gemma3-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        family="dense",
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,                 # gemma3 uses wide heads (16*256=4096)
        d_ff=15_360,
        vocab_size=262_144,
        pattern=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,),
        sliding_window=1024,
        use_qk_norm=True,
        logit_softcap=0.0,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        max_context=131_072,
        notes="5:1 local:global; long_500k runs (bounded KV on 5/6 layers)",
    )
