"""command-r-35b [dense]: 40L, d_model=8192, 64H (GQA kv=8), d_ff=22528,
vocab=256000.  GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL, register


@register("command-r-35b")
def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22_528,
        vocab_size=256_000,
        pattern=(ATTN_GLOBAL,),
        rope_theta=8_000_000.0,
        tie_embeddings=True,
        max_context=131_072,
        notes="no biases anywhere; parallel attention+FFN residual stream",
    )
