"""dbrx-132b [moe]: 40L, d_model=6144, 48H (GQA kv=8), d_ff=10752 (per
expert), vocab=100352.  16 experts top-4, fine-grained.
[hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig, MOE, register


@register("dbrx-132b")
def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=10_752,
        vocab_size=100_352,
        pattern=(MOE,),
        moe=MoEConfig(num_experts=16, top_k=4, d_expert=10_752),
        rope_theta=500_000.0,
        max_context=32_768,
    )
