"""qwen2-moe-a2.7b [moe]: 24L, d_model=2048, 16H (GQA kv=16), d_ff=1408
(per expert), vocab=151936.  60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, MOE, register


@register("qwen2-moe-a2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,                    # routed expert hidden
        vocab_size=151_936,
        pattern=(MOE,),
        moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408,
                      num_shared_experts=4, d_shared_expert=5632),
        rope_theta=1_000_000.0,
        max_context=32_768,
        notes="fine-grained experts; 60 padded to 64 for expert-parallel",
    )
