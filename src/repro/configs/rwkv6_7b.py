"""rwkv6-7b [ssm]: 32L, d_model=4096, attention-free, d_ff=14336,
vocab=65536.  RWKV-6 "Finch" with data-dependent decay.  [arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, SSMConfig, RWKV6, register


@register("rwkv6-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,                 # rwkv6 heads = d_model / 64
        num_kv_heads=64,
        head_dim=64,
        d_ff=14_336,
        vocab_size=65_536,
        pattern=(RWKV6,),
        ssm=SSMConfig(state_dim=64, head_dim=64, chunk_size=128),
        rope_theta=0.0,
        max_context=1 << 30,          # state-based: unbounded context
        notes="Finch: data-dependent decay w_t; constant-size recurrent state",
    )
