"""Sharded checkpointing with atomic commit and elastic restore.

Failure-handling substrate (paper §5.3.2 adapted): a *cut* of the training
resource graph is the optimizer update -- params + optimizer state + step +
data cursor fully determine everything downstream, so persisting them at a
cut gives at-least-once recovery without replaying the whole job.

Layout (one directory per step):
    ckpt_dir/step_000123.tmp/   -> written, fsynced
        manifest.json            (tree structure, shapes, dtypes, hashes)
        arr_00000.npy ...        (one file per leaf, host-local shard)
    ckpt_dir/step_000123/        (atomic rename = commit record)

Restore supports *elastic resharding*: arrays are loaded as full logical
values and re-placed under the (possibly different) target mesh's
shardings, so a job checkpointed on 512 chips restarts on 256 (the
resource-centric re-materialization of the same graph on fewer resources).
Writes happen on a background thread (async checkpointing) so the step
loop is not blocked."""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_BF16 = np.dtype(ml_dtypes.bfloat16)


def _to_savable(arr: np.ndarray):
    """numpy can't serialize bfloat16: store as uint16 + logical dtype."""
    if arr.dtype == _BF16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _from_saved(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical == "bfloat16":
        return arr.view(_BF16)
    return arr


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Blocking save with atomic commit.  Returns the committed path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (key, leaf) in enumerate(_flatten_with_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        arr_s, logical = _to_savable(arr)
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr_s, allow_pickle=False)
        with open(os.path.join(tmp, fname), "rb") as f:
            digest = hashlib.sha256(f.read(1 << 20)).hexdigest()[:16]
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": logical, "hash_head": digest})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)          # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int], like: Any,
                       shardings: Optional[Any] = None,
                       ) -> Tuple[Any, Dict, int]:
    """Restore into the structure of ``like`` (validates shapes/dtypes).

    ``shardings``: optional matching tree of NamedShardings -- restoring
    under a different mesh re-places every leaf (elastic restart)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for ent in manifest["leaves"]:
        raw = np.load(os.path.join(path, ent["file"]), allow_pickle=False)
        leaves[ent["key"]] = _from_saved(raw, ent["dtype"])
    like_flat = _flatten_with_paths(like)
    out_leaves = []
    shard_flat = (None if shardings is None
                  else [s for _, s in _flatten_with_paths(shardings)])
    for i, (key, leaf) in enumerate(like_flat):
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = leaves[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"restore target {want_shape}")
        dtype = np.dtype(leaf.dtype) if hasattr(leaf, "dtype") else arr.dtype
        if arr.dtype != dtype:
            arr = arr.astype(dtype)
        if shard_flat is not None and shard_flat[i] is not None:
            out_leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            out_leaves.append(jax.device_put(arr))
    treedef = jax.tree.structure(like)
    return (jax.tree.unflatten(treedef, out_leaves), manifest["extra"],
            step)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with at-most-one in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saved_steps: List[int] = []

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False):
        self.wait()
        # snapshot to host BEFORE returning control (consistent cut)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra)
                self.saved_steps.append(step)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def _gc(self):
        steps = sorted(self.saved_steps)
        while len(steps) > self.keep:
            s = steps.pop(0)
            path = os.path.join(self.ckpt_dir, f"step_{s:08d}")
            if os.path.exists(path):
                shutil.rmtree(path)
            self.saved_steps.remove(s)
