"""Failure recovery and elasticity: graph cuts, stragglers, elastic resize.

Paper §5.3.2: on failure, discard the crashed component and every data
component it accesses, find the latest *cut* of the resource graph whose
crossing edges are all persistently recorded, and re-execute from there.

Training substrate: the cut is the last committed checkpoint (params + opt
state + data cursor); "discard crashed components" = rebuild device state;
"re-execute from recorded inputs" = deterministic data pipeline replay from
the cursor.  Elastic resize re-materializes the SAME resource graph on a
smaller/larger mesh: the materializer produces a new plan, and the restore
path re-places every leaf under the new shardings.

Straggler mitigation: per-step wall-time watchdog based on a decayed
history of step times -- a step exceeding quantile(0.99) * slack flags the
participating host set; the driver responds by checkpoint-and-reshard
(shrinking the mesh away from the slow host), the TPU-pragmatic analog of
work re-dispatch (you cannot reassign a single chip's shard mid-step)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.history import DecayedHistogram
from repro.core.materializer import MeshSpec, Plan, materialize


@dataclass
class RecoveryPoint:
    step: int
    ckpt_path: str
    data_cursor: int
    mesh_name: str


class CutTracker:
    """Tracks the latest persisted cut; decides what to re-execute."""

    def __init__(self):
        self.points: List[RecoveryPoint] = []

    def record(self, p: RecoveryPoint) -> None:
        self.points.append(p)

    def latest(self) -> Optional[RecoveryPoint]:
        return self.points[-1] if self.points else None

    def replay_span(self, failed_step: int) -> Tuple[int, int]:
        """(restart_step, lost_steps) after a failure at failed_step."""
        p = self.latest()
        start = p.step if p else 0
        return start, max(failed_step - start, 0)


class StragglerWatchdog:
    """Flags steps that exceed the historical p99 by a slack factor."""

    def __init__(self, slack: float = 2.0, warmup: int = 8):
        self.hist = DecayedHistogram(lo=1e-4, hi=1e4)
        self.slack = slack
        self.warmup = warmup
        self.flags: List[Tuple[int, float, float]] = []

    def observe(self, step: int, wall_s: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if self.hist.count >= self.warmup:
            thresh = self.hist.quantile(0.99) * self.slack
            if wall_s > thresh:
                is_straggler = True
                self.flags.append((step, wall_s, thresh))
        self.hist.observe(wall_s)
        return is_straggler


@dataclass
class ElasticPolicy:
    """Mesh downsize ladder on persistent failure/straggle."""
    mesh_options: List[MeshSpec]
    current: int = 0

    def current_mesh(self) -> MeshSpec:
        return self.mesh_options[self.current]

    def shrink(self) -> Optional[MeshSpec]:
        if self.current + 1 >= len(self.mesh_options):
            return None
        self.current += 1
        return self.mesh_options[self.current]

    def grow(self) -> Optional[MeshSpec]:
        if self.current == 0:
            return None
        self.current -= 1
        return self.mesh_options[self.current]


def elastic_replan(cfg, shape, new_mesh: MeshSpec,
                   history=None) -> Plan:
    """Re-materialize the same resource graph on a different mesh.

    This is the crux of resource-centric recovery: nothing about the
    application changes -- only the physical materialization."""
    return materialize(cfg, shape, new_mesh, history=history)


class FailureInjector:
    """Deterministic fault injection for tests/benchmarks."""

    def __init__(self, fail_at_steps: Tuple[int, ...] = ()):
        self.fail_at = set(fail_at_steps)
        self.injected: List[int] = []

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.injected.append(step)
            raise RuntimeError(f"injected failure at step {step}")
