"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid: (batch, heads, num_chunks), chunk dimension sequential; the (P x N)
fp32 SSM state sits in VMEM scratch.  Per chunk: the (C x C) decay-masked
``C B^T`` product runs on the MXU; the inter-chunk term contracts the
carried state with C_t.  Matches models/mamba2.ssd_chunked (the oracle is
ref.ssd_ref / the per-step recurrence)."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CLAMP = -30.0


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sout_ref, s_scr,
                *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (C, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (C, 1)
    a = a_ref[0, 0].astype(jnp.float32)        # (C, 1)
    bmat = b_ref[0].astype(jnp.float32)        # (C, N)
    cmat = c_ref[0].astype(jnp.float32)        # (C, N)

    csum = jnp.cumsum(a, axis=0)               # (C, 1) inclusive
    total = csum[-1:]
    dec_in = jnp.exp(jnp.maximum(csum, CLAMP))
    dec_out = jnp.exp(jnp.maximum(total - csum, CLAMP))

    state = s_scr[...]                          # (P, N)
    y_inter = jax.lax.dot_general(cmat, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * dec_in                  # (C, P)

    att = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C, C)
    c = att.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    pair = jnp.exp(jnp.clip(csum - csum[:, 0][None, :], CLAMP, -CLAMP))
    w = jnp.where(jj <= ii, att * pair, 0.0)    # (C, C)
    y_intra = jax.lax.dot_general(w, x * dt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    kdec = bmat * (dt * dec_out)                # (C, N)
    s_new = state * jnp.exp(jnp.maximum(total, 2 * CLAMP))[0] + \
        jax.lax.dot_general(x, kdec, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (P, N)
    s_scr[...] = s_new
    y_ref[0, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _fin():
        sout_ref[0, 0] = s_new.astype(sout_ref.dtype)


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = True
             ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, H, S, P); dt, a: (B, H, S); b, c: (B, S, N).

    Returns (y (B,H,S,P) fp32, final state (B,H,P,N) fp32)."""
    bsz, h, s, p_dim = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    nc = s // chunk
    dt3 = dt[..., None]
    a3 = a[..., None]
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, sout = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p_dim),
                         lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p_dim),
                         lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, p_dim, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, s, p_dim), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p_dim, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p_dim, n), jnp.float32)],
        interpret=interpret,
    )(x, dt3, a3, b, c)
    return y, sout
