"""Jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; on CPU
(this container) ``interpret=True`` executes the kernel bodies in Python
for correctness validation.  ``flash_attention`` wires the fwd/bwd kernels
through jax.custom_vjp so training uses the kernel gradient path.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _paged
from repro.kernels import rmsnorm as _rms
from repro.kernels import rwkv6_scan as _rwkv
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention with custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """q: (B, H, S, D); k, v: (B, KVH, S, D) -> (B, H, S, D)."""
    o, _ = _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=_interpret())
    return o


def _fa_fwd(q, k, v, causal, window, block_q, block_k):
    o, lse = _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                     block_q=block_q, block_k=block_k,
                                     interpret=_interpret())
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, block_q, block_k, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _fa.flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def flash_attention_bshd(q, k, v, *, causal=True, window=0, block_q=128,
                         block_k=128):
    """(B, S, H, D)-layout convenience wrapper (model-layer layout)."""
    o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal, window,
                        block_q, block_k)
    return o.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# decode attention / scans / norm (inference or fwd-only paths)
# ---------------------------------------------------------------------------

def decode_attention(q, k, v, valid_len, *, block_s: int = 512):
    return _dec.decode_attention(q, k, v, valid_len, block_s=block_s,
                                 interpret=_interpret())


def rwkv6_wkv(r, k, v, logw, u, *, chunk: int = 128):
    return _rwkv.rwkv6_wkv(r, k, v, logw, u, chunk=chunk,
                           interpret=_interpret())


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128):
    return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk,
                         interpret=_interpret())


def rmsnorm(x, gain, *, eps: float = 1e-6, block_rows: int = 128):
    return _rms.rmsnorm(x, gain, eps=eps, block_rows=block_rows,
                        interpret=_interpret())


def paged_attention(q, k_pages, v_pages, page_table, valid_len, *,
                    window: int = 0, ring: bool = False):
    return _paged.paged_attention(q, k_pages, v_pages, page_table, valid_len,
                                  window=window, ring=ring,
                                  interpret=_interpret())
