"""Pallas TPU kernel for the RWKV-6 chunked WKV recurrence.

Grid: (batch, heads, num_chunks) with the chunk dimension sequential; the
(hd x hd) fp32 recurrent state lives in VMEM scratch, carried across chunk
iterations (initialized at chunk 0, written out at the last chunk).  Within
a chunk the math matches models/rwkv6.wkv_chunked: intra-chunk pairwise
decay attention + inter-chunk state contribution, all on (C x hd) tiles so
the pairwise (C x C) products run on the MXU.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CLAMP = -30.0


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sout_ref, s_scr,
                *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)          # (C, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # (1, hd)

    csum = jnp.cumsum(lw, axis=0)
    total = csum[-1:]
    dec_in = jnp.exp(jnp.maximum(csum - lw, CLAMP))
    dec_out = jnp.exp(jnp.maximum(total - csum, CLAMP))

    state = s_scr[...]                           # (hd, hd)
    o_inter = jax.lax.dot_general(r * dec_in, state, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    rd = r * dec_in
    kd = k * jnp.exp(jnp.clip(-csum, CLAMP, -CLAMP))
    att = jax.lax.dot_general(rd, kd, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C, C)
    c = att.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    att = jnp.where(jj < ii, att, 0.0)
    diag = jnp.sum(r * k * u, axis=1)            # (C,)
    att = att + jnp.where(jj == ii, diag[:, None], 0.0)
    o_intra = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    kdec = k * dec_out
    s_new = state * jnp.exp(jnp.maximum(total, 2 * CLAMP))[0][:, None] + \
        jax.lax.dot_general(kdec, v, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s_scr[...] = s_new
    o_ref[0, 0] = (o_inter + o_intra).astype(o_ref.dtype)

    @pl.when(ci == nc - 1)
    def _fin():
        sout_ref[0, 0] = s_new.astype(sout_ref.dtype)


def rwkv6_wkv(r, k, v, logw, u, *, chunk: int = 128, interpret: bool = True
              ) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,logw: (B, H, S, hd); u: (H, hd).

    Returns (o (B,H,S,hd) fp32, final_state (B,H,hd,hd) fp32).
    Zero initial state (use the jnp path for chained segments)."""
    b, h, s, hd = r.shape
    chunk = min(chunk, s)
    nc = s // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    o, sout = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, hd), lambda b_, h_, c_: (h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return o, sout
