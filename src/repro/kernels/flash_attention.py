"""Pallas TPU flash-attention kernel (forward + backward).

TPU-native design (vs. a CUDA port):
  * Tiles are MXU/VMEM-shaped: (block_q x head_dim) / (block_k x head_dim)
    blocks staged HBM->VMEM by BlockSpecs; dot_generals hit the 128x128 MXU
    (ops.py pads odd head dims to multiples of 128 on real hardware).
  * GQA is folded into the BlockSpec index maps (KV block index = q_head //
    group): no materialized head expansion in HBM.
  * Online-softmax running state (m, l, acc) lives in VMEM scratch and
    persists across the sequential k-block grid dimension.
  * Causal/sliding-window masks come from program ids; fully-masked tiles
    are skipped with pl.when (TPU analog of CUDA block skipping).

Backward is the standard two-pass flash recipe: recompute p from the saved
logsumexp; pass A accumulates dq over k-blocks, pass B accumulates (dk, dv)
over q-blocks.  ref.py holds the jnp oracle; ops.py wires custom_vjp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask(block_q, block_k, q_start, k_start, causal, window):
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    m = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        m = m & (kpos <= qpos)
    if window > 0:
        m = m & (kpos > qpos - window)
    return m


def _tile_live(q_start, k_start, block_q, block_k, causal, window):
    """Whether any element of this (q, k) tile is unmasked."""
    live = jnp.asarray(True)
    if causal:
        live = live & (k_start <= q_start + block_q - 1)
    if window > 0:
        live = live & (k_start + block_k - 1 > q_start - window)
    return live


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, causal, window, block_q, block_k):
    qi, ki = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(_tile_live(q_start, k_start, block_q, block_k, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _mask(block_q, block_k, q_start, k_start, causal, window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l))[:, 0]


def flash_attention_fwd(q, k, v, *, causal=True, window=0, block_q=128,
                        block_k=128, interpret=True):
    """q: (B, H, S, D); k, v: (B, KVH, S, D) -> (o, lse (B,H,S) fp32)."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq, nk = s // block_q, s // block_k
    kernel = functools.partial(_fwd_kernel, scale=d ** -0.5, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, h_, q_, k_: (b_, h_, q_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_scr, *, scale, causal, window, block_q, block_k):
    qi, ki = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_start, k_start = qi * block_q, ki * block_k

    @pl.when(_tile_live(q_start, k_start, block_q, block_k, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _mask(block_q, block_k, q_start, k_start, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _fin():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, window,
                block_q, block_k):
    # grid: (b, kv_head, k_block, q_block, group)
    ki, qi, gi = pl.program_id(2), pl.program_id(3), pl.program_id(4)
    nq, ng = pl.num_programs(3), pl.num_programs(4)

    @pl.when((qi == 0) & (gi == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start, k_start = qi * block_q, ki * block_k

    @pl.when(_tile_live(q_start, k_start, block_q, block_k, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _mask(block_q, block_k, q_start, k_start, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)          # (bq, bk)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bk, d)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bk, d)

    @pl.when((qi == nq - 1) & (gi == ng - 1))
    def _fin():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=0,
                        block_q=128, block_k=128, interpret=True):
    b, h, s, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq, nk = s // block_q, s // block_k
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                                  # (B, H, S)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=d ** -0.5, causal=causal,
                          window=window, block_q=block_q, block_k=block_k),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, h_, q_, k_: (b_, h_, q_)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, h_, q_, k_: (b_, h_, q_)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv: grid over kv heads; inner-most dims iterate q blocks x group
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=d ** -0.5, causal=causal,
                          window=window, block_q=block_q, block_k=block_k),
        grid=(b, kvh, nk, nq, g),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, kh, k_, q_, g_: (b_, kh * g + g_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, kh, k_, q_, g_: (b_, kh, k_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, kh, k_, q_, g_: (b_, kh, k_, 0)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, kh, k_, q_, g_: (b_, kh * g + g_, q_, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, kh, k_, q_, g_: (b_, kh * g + g_, q_)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, kh, k_, q_, g_: (b_, kh * g + g_, q_)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, kh, k_, q_, g_: (b_, kh, k_, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, kh, k_, q_, g_: (b_, kh, k_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, s, d), k.dtype),
            jax.ShapeDtypeStruct((b, kvh, s, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
