"""Fused RMSNorm Pallas kernel: one HBM read, fp32 statistics in-register.

Grid over row blocks; each block computes mean-square and the scaled output
in a single VMEM residency (XLA emits separate reduce + mul passes on CPU;
on TPU this saves one full activation round-trip)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)             # (R, D)
    g = g_ref[...].astype(jnp.float32)             # (1, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + g)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, gain: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 128, interpret: bool = True) -> jax.Array:
    """x: (..., D); gain: (D,).  (1+gain) parameterization (see layers)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows //= 2
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, gain.reshape(1, d))
    return out.reshape(orig_shape)
