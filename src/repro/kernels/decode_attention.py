"""Pallas TPU decode-attention kernel (single-token query, long KV).

Decode is memory-bound: the whole KV cache streams HBM->VMEM once while
queries stay resident.  Grid: (batch, kv_heads, seq_blocks) with the seq
dimension sequential; the per-(batch, kv-head) online-softmax state for all
``group`` grouped queries is VMEM scratch.  GQA stays folded (the q block
carries the whole group for one KV head), so arithmetic intensity per KV
byte is maximized -- the TPU analog of flash-decoding's split-K, with the
cross-shard combine handled at the SPMD level (models/attention
seqshard path) rather than inside the kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, block_s: int, scale: float):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_len = len_ref[0]
    s_start = si * block_s

    @pl.when(s_start < valid_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bs, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < valid_len, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(pos < valid_len, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, *, block_s: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q: (B, H, D); k, v: (B, KVH, S, D); valid_len: () or (B,) int32.

    Returns (B, H, D).  Attends over positions [0, valid_len)."""
    b, h, d = q.shape
    kvh, s = k.shape[1], k.shape[2]
    g = h // kvh
    block_s = min(block_s, s)
    ns = s // block_s
    qg = q.reshape(b, kvh, g, d)
    vlen = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_s=block_s, scale=d ** -0.5),
        grid=(b, kvh, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, s_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda b_, h_, s_: (b_, h_, s_, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda b_, h_, s_: (b_, h_, s_, 0)),
            pl.BlockSpec((1,), lambda b_, h_, s_: (b_,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, s_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, vlen)
    return out.reshape(b, h, d)
