"""Pure-jnp oracles for every Pallas kernel (exact, unchunked math).

Each oracle is the semantic ground truth the kernels are tested against in
tests/test_kernels.py across shape/dtype sweeps."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,S,D); k,v: (B,KVH,S,D) -> (B,H,S,D).  Full softmax oracle."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    k = jnp.repeat(k, h // kvh, axis=1)
    v = jnp.repeat(v, h // kvh, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = mask & (ki <= qi)
    if window > 0:
        mask = mask & (ki > qi - window)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, valid_len):
    """q: (B,H,D); k,v: (B,KVH,S,D); valid_len () or (B,) -> (B,H,D)."""
    b, h, d = q.shape
    kvh, s = k.shape[1], k.shape[2]
    k = jnp.repeat(k, h // kvh, axis=1)
    v = jnp.repeat(v, h // kvh, axis=1)
    scores = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    vlen = jnp.broadcast_to(jnp.asarray(valid_len), (b,))
    mask = jnp.arange(s)[None, None, :] < vlen[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_wkv_ref(r, k, v, logw, u) -> Tuple[jax.Array, jax.Array]:
    """Per-timestep recurrence oracle.  r,k,v,logw: (B,H,S,hd); u: (H,hd).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t;  o_t = r_t (S_{t-1} + u . k_t^T v_t)
    Returns (o (B,H,S,hd) fp32, final state (B,H,hd,hd) fp32)."""
    b, h, s, hd = r.shape
    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    u32 = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp                       # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt,
                       state + u32[None, :, :, None] * kv)
        state = state * wt[..., None] + kv
        return state, o

    xs = tuple(t.transpose(2, 0, 1, 3) for t in (r32, k32, v32, w))
    state0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    state, o = jax.lax.scan(step, state0, xs)
    return o.transpose(1, 2, 0, 3), state


def ssd_ref(x, dt, a, b, c) -> Tuple[jax.Array, jax.Array]:
    """Per-timestep SSD oracle.  x: (B,H,S,P); dt,a: (B,H,S); b,c: (B,S,N).

    h_t = exp(a_t) h_{t-1} + dt_t B_t x_t^T;  y_t = C_t h_t
    Returns (y (B,H,S,P) fp32, final state (B,H,P,N) fp32)."""
    bsz, h, s, p = x.shape
    n = b.shape[-1]
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    c32 = c.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, at, bt, ct = inp                  # (B,H,P), (B,H), .., (B,N)
        upd = dtt[..., None, None] * jnp.einsum("bhp,bn->bhpn", xt, bt)
        state = state * jnp.exp(at)[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    xs = (x32.transpose(2, 0, 1, 3), dt32.transpose(2, 0, 1),
          a32.transpose(2, 0, 1), b32.transpose(1, 0, 2),
          c32.transpose(1, 0, 2))
    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    state, y = jax.lax.scan(step, state0, xs)
    return y.transpose(1, 2, 0, 3), state


def rmsnorm_ref(x, gain, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * (1.0 + gain.astype(jnp.float32))).astype(x.dtype)
