"""Pallas TPU paged-attention kernel (decode over a paged KV pool).

The serving engine stores KV in fixed-size pages granted by the sizing LP
(serving/kv_cache.py); decode must attend over each request's page list.
TPU-native design: the page table is a *scalar-prefetch* operand --
``pltpu.PrefetchScalarGridSpec`` hands it to the BlockSpec index maps, so
the pipeline DMAs exactly the pages named by the table (no gather of the
whole pool).  Grid: (batch, kv_heads, max_pages) with the page dimension
sequential; online-softmax state for the grouped queries lives in VMEM
scratch.  Out-of-range pages (table entry < 0) are skipped via pl.when --
requests shorter than max_pages cost only their own pages' DMAs.

Sliding-window (ATTN_LOCAL) layers run the same kernel with
``window > 0``: only keys at positions ``(pos - window, pos]`` score.
With ``ring=True`` the page table is a fixed *ring* of
``ceil(window/PAGE_SIZE)+1`` pages -- token position ``p`` lives at ring
slot ``p % (max_pages * page_size)``, so a slot's absolute position is
recovered as the latest ``p' <= pos`` congruent to the slot index
(modulo the ring size), exactly mirroring the dense ring cache in
``models/attention.self_attention_decode``.

Prefix-cache interaction (serving/prefix_cache.py): a request's page
table may MIX two id classes -- leading entries that are cache-owned
PHYSICAL page ids (refcounted, read-only prefix pages shared across
requests and tenants) followed by view-translated private ids.  The
kernel is oblivious: both classes index the same pool-sized arrays, and
decode only ever *writes* the private tail (the write position ``p``
satisfies ``p // page_size >= len(shared_pages)``), so shared pages are
strictly read-only here.  Nothing in the kernel changes; this note
exists because the table is no longer uniformly view-local.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _slot_positions(slot, last, *, window: int, ring: bool, ring_tokens: int):
    """(abs position, valid?) of ring/linear cache slots given the last
    written position ``last`` (= valid_len - 1).

    Linear tables store position ``s`` at slot ``s``.  Ring tables store
    position ``p`` at slot ``p % ring_tokens``; the slot's current
    occupant is the LATEST ``p' <= last`` congruent to the slot index,
    i.e. ``last - ((last - s) % ring_tokens)`` (negative -> never
    written).  ``window > 0`` additionally masks positions at or below
    ``last - window``."""
    if ring:
        pos = last - jnp.remainder(last - slot, ring_tokens)
    else:
        pos = slot
    ok = (pos >= 0) & (pos <= last)
    if window > 0:
        ok = ok & (pos > last - window)
    return pos, ok


def _paged_kernel(table_ref, q_ref, k_ref, v_ref, len_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page_size: int, scale: float,
                  window: int, ring: bool):
    b, h, pi = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    page_id = table_ref[b, pi]
    valid_len = len_ref[b]
    s_start = pi * page_size
    # a ring page can hold live tokens regardless of its table index, so
    # the start-beyond-length early-exit only applies to linear tables
    live = (page_id >= 0) if ring else (page_id >= 0) & (s_start < valid_len)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (page, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        slot = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _, ok = _slot_positions(slot, valid_len - 1, window=window,
                                ring=ring, ring_tokens=np_ * page_size)
        s = jnp.where(ok, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == np_ - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, valid_len: jax.Array, *,
                    window: int = 0, ring: bool = False,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, D); k/v_pages: (P, page, KV, D) pool; page_table:
    (B, max_pages) int32 (-1 padded); valid_len: (B,) total tokens.

    Table entries are PHYSICAL page ids: the pool arrays may be a
    pod-shared :class:`~repro.serving.model_runner.KVArrayStore` aliased
    by several tenants, and only physical ids are unique across it --
    callers translate view-local ids (``PoolView.to_physical``) before
    building the table.

    ``window > 0`` masks keys outside the last ``window`` positions;
    ``ring=True`` additionally treats the table as a position-modular
    ring of ``max_pages`` pages (sliding-window layers' bounded tables).

    Returns (B, H, D)."""
    b, h, d = q.shape
    pool, page, kvh, _ = k_pages.shape
    g = h // kvh
    max_pages = page_table.shape[1]
    qg = q.reshape(b, kvh, g, d)
    # pool laid out (KV, P, page, d) so a block is one head's one page
    kp = k_pages.transpose(2, 0, 1, 3)
    vp = v_pages.transpose(2, 0, 1, 3)
    vlen = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, p_, tbl: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, page, d),
                         lambda b_, h_, p_, tbl: (h_, jnp.maximum(
                             tbl[b_, p_], 0), 0, 0)),
            pl.BlockSpec((1, 1, page, d),
                         lambda b_, h_, p_, tbl: (h_, jnp.maximum(
                             tbl[b_, p_], 0), 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h_, p_, tbl: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, page_size=page,
                          scale=d ** -0.5, window=window, ring=ring),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), qg, kp, vp, vlen)
    return out.reshape(b, h, d)


def paged_attention_ref(q, k_pages, v_pages, page_table, valid_len, *,
                        window: int = 0, ring: bool = False):
    """Gather-based jnp oracle (same window/ring semantics as the
    kernel)."""
    b, h, d = q.shape
    pool, page, kvh, _ = k_pages.shape
    max_pages = page_table.shape[1]
    safe = jnp.maximum(page_table, 0)                        # (B, MP)
    k = k_pages[safe]                                        # (B, MP, page, KV, d)
    v = v_pages[safe]
    k = k.reshape(b, max_pages * page, kvh, d)
    v = v.reshape(b, max_pages * page, kvh, d)
    k = jnp.repeat(k, h // kvh, axis=2)
    v = jnp.repeat(v, h // kvh, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    vlen = jnp.broadcast_to(jnp.asarray(valid_len), (b,))
    slot = jnp.arange(max_pages * page)[None, None, :]
    in_page = (jnp.repeat(page_table >= 0, page, axis=1))[:, None, :]
    _, ok = _slot_positions(slot, vlen[:, None, None] - 1, window=window,
                            ring=ring, ring_tokens=max_pages * page)
    mask = ok & in_page
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(mask, probs, 0.0)   # fully-masked rows stay finite
    return jnp.einsum("bhs,bshd->bhd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
