"""Paper Figs. 11-13: the video-transcoding pipeline analog, plus the
multi-tenant sharing experiment (§9.3 resource-centric co-location).

Part 1 (fig11_video): three "resolutions" = three request-length classes
(240P/720P/4K -> short/medium/long prompts).  Compare:
  * adaptive (history-sized page grants, continuous batching) vs
  * function-static (every request peak-provisioned, gg/ExCamera style).

Part 2 (fig12_tenancy): the SAME three classes as three serve
Applications co-located on one pod via ``Cluster.submit()``.  Compare:
  * shared  -- one pod-level SharedPagePool, fair-share cross-app
    preemption, per-app history-driven grants; vs
  * private -- each app brings pool_pages/3 of its own (per-function
    peak provisioning of the pool itself).

Part 3 (fig_swa): a sliding-window tenant (reduced gemma3, 5 local : 1
global) serving long generations through the paged backend on the
pod-shared pool.  Compare ring page accounting (local layers hold a
fixed ``ceil(window/PAGE_SIZE)+1``-page ring) against the no-ring arm
(local layers charged like global growing tables).  Emitted as its own
``BENCH_serving_swa.json`` artifact.

Derived: completion wall time, pool utilization, denial/preempt counts.
"""

import argparse
import time

import numpy as np

from benchmarks.common import emit_json, row, rows_mark
from repro.core.history import HistoryStore
from repro.runtime import Application, Cluster, JaxExecutor, NullExecutor
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PAGE_SIZE, PagePool, Request

CLASSES = {"240p": (64, 16), "720p": (512, 64), "4k": (2048, 256)}


def run_policy(policy: str, prompt: int, gen: int, n: int = 64):
    hist = HistoryStore()
    if policy == "history":
        for _ in range(40):
            hist.observe("serve", "request", "pages",
                         -(-(prompt + gen) // PAGE_SIZE))
    pool = PagePool(512, history=hist, policy=policy,
                    fixed_init_pages=-(-(2048 + 256) // PAGE_SIZE))  # peak
    eng = ServingEngine(pool, max_batch=16)
    rng = np.random.default_rng(0)
    for i in range(n):
        p = int(prompt * rng.uniform(0.6, 1.4))
        eng.submit(Request(f"r{i}", p, gen))
    peak_util = 0.0
    steps = 0
    t0 = time.perf_counter()
    while eng.step():
        peak_util = max(peak_util, pool.utilization)
        steps += 1
        if steps > 100_000:
            break
    wall = (time.perf_counter() - t0) * 1e6
    return wall, eng.stats, peak_util, pool


def run_tenancy(shared: bool, n_per_app: int = 32, pool_pages: int = 192,
                max_steps: int = 200_000):
    """Three request-length-class apps on one pod, through the runtime."""
    hist = HistoryStore()
    cluster = Cluster(pods=1, history=hist, executor=NullExecutor(),
                      pool_pages=pool_pages if shared else None)
    handles = {}
    rng = np.random.default_rng(0)
    for cls, (prompt, gen) in CLASSES.items():
        app = Application.serve(
            "tinyllama-1.1b", reduced=True, name=f"app-{cls}",
            max_batch=8, private_pool=not shared,
            pool_pages=pool_pages if shared else pool_pages // len(CLASSES))
        h = cluster.submit(app)
        for i in range(n_per_app):
            p = int(prompt * rng.uniform(0.6, 1.4))
            h.submit_request(Request(f"{cls}-r{i}", p, gen))
        handles[cls] = h

    t0 = time.perf_counter()
    peak_util, steps, alive = 0.0, 0, set(CLASSES)
    while alive and steps < max_steps:
        for cls in list(alive):
            if not handles[cls].step()["alive"]:
                alive.discard(cls)
        if shared:
            pool = cluster.pod_pool("pod0")
            peak_util = max(peak_util, pool.utilization)
        else:
            used = sum(h.engine.pool.num_pages * h.engine.pool.utilization
                       for h in handles.values())
            peak_util = max(peak_util, used / pool_pages)
        steps += 1
    wall = (time.perf_counter() - t0) * 1e6
    stats = {cls: handles[cls].serving_stats() for cls in CLASSES}
    for h in handles.values():
        h.release()
    return wall, stats, peak_util


def run_swa(rings: bool, *, n: int = 4, prompt: int = 96, gen: int = 280,
            pool_pages: int = 64, max_steps: int = 5_000):
    """One sliding-window tenant on the pod-shared pool, paged backend.

    ``rings=False`` is the baseline arm: local-attention layers are
    charged growing page tables like global ones (decode stays windowed
    and token-identical -- only the page accounting differs)."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=pool_pages)
    h = cluster.submit(Application.serve(
        "gemma3-12b", reduced=True, name="swa-tenant", max_batch=4,
        backend="paged", swa_rings=rings, policy="fixed"))
    for i in range(n):
        h.submit_request(Request(f"swa-r{i}", prompt, gen))
    pool = h.engine.pool
    t0 = time.perf_counter()
    peak_util = util_sum = 0.0
    peak_local = steps = 0
    while h.step()["alive"] and steps < max_steps:
        u = pool.utilization
        peak_util = max(peak_util, u)
        util_sum += u
        peak_local = max(peak_local, getattr(pool, "used_local", 0))
        steps += 1
    wall = (time.perf_counter() - t0) * 1e6
    stats = h.serving_stats()
    traces = h.runner.decode_traces
    h.release()
    return (wall, stats, peak_util, util_sum / max(steps, 1), traces,
            peak_local)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per class (fig11) / per app (fig12)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters for CI drift detection")
    args = ap.parse_args()
    n = 6 if args.smoke else args.requests

    for cls, (prompt, gen) in CLASSES.items():
        for policy in ("history", "fixed"):
            # 'fixed' with peak init pages == gg-style peak provisioning
            wall, stats, util, pool = run_policy(policy, prompt, gen, n=n)
            name = "adaptive" if policy == "history" else "static_peak"
            row(f"fig11_video/{cls}/{name}", wall / max(stats.decode_steps, 1),
                f"completed={stats.completed};decode_steps={stats.decode_steps};"
                f"peak_util={util:.2f};denials={pool.stats['denials']};"
                f"preempt={stats.preempted}")

    n_mt = 4 if args.smoke else max(args.requests // 2, 8)
    for mode in ("shared", "private"):
        wall, stats, util = run_tenancy(mode == "shared", n_per_app=n_mt)
        done = sum(s["completed"] for s in stats.values())
        preempt = sum(s["preempted"] for s in stats.values())
        denials = sum(s["pool"]["denials"] for s in stats.values())
        per_app = ";".join(
            f"{cls}:done={s['completed']},preempt={s['preempted']}"
            for cls, s in stats.items())
        row(f"fig12_tenancy/{mode}", wall,
            f"completed={done};peak_util={util:.2f};preempt={preempt};"
            f"denials={denials};{per_app}")
    emit_json("serving_pipeline", extra={"smoke": args.smoke})

    # Part 3: sliding-window ring pages on the pod-shared pool, emitted
    # as its own artifact (BENCH_serving_swa.json)
    # generation must outgrow the ring space (ring_pages * PAGE_SIZE =
    # 256 tokens at the reduced window) for the ring's bounded footprint
    # to show: total length 96 + gen spans 4-5 global pages
    mark = rows_mark()
    gen = 300 if args.smoke else 420
    for rings in (True, False):
        wall, stats, peak, mean, traces, peak_local = run_swa(
            rings, n=4, gen=gen)
        name = "ring" if rings else "no_ring"
        row(f"fig_swa/{name}", wall / max(stats["decode_steps"], 1),
            f"completed={stats['completed']};peak_util={peak:.3f};"
            f"mean_util={mean:.3f};peak_local_pages={peak_local};"
            f"decode_compiles={traces}")
    emit_json("serving_swa", extra={"smoke": args.smoke, "gen": gen},
              rows_from=mark)


if __name__ == "__main__":
    main()
