"""Paper Figs. 11-13: the video-transcoding pipeline analog.

Three "resolutions" = three request-length classes (240P/720P/4K ->
short/medium/long prompts).  Compare:
  * adaptive (history-sized page grants, continuous batching) vs
  * function-static (every request peak-provisioned, gg/ExCamera style).

Derived: completion wall time, pool utilization, denial/preempt counts.
"""

import numpy as np

from benchmarks.common import row, timeit
from repro.core.history import HistoryStore
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PAGE_SIZE, PagePool, Request

CLASSES = {"240p": (64, 16), "720p": (512, 64), "4k": (2048, 256)}


def run_policy(policy: str, prompt: int, gen: int, n: int = 64):
    hist = HistoryStore()
    if policy == "history":
        for _ in range(40):
            hist.observe("serve", "request", "pages",
                         -(-(prompt + gen) // PAGE_SIZE))
    pool = PagePool(512, history=hist, policy=policy,
                    fixed_init_pages=-(-(2048 + 256) // PAGE_SIZE))  # peak
    eng = ServingEngine(pool, max_batch=16)
    rng = np.random.default_rng(0)
    for i in range(n):
        p = int(prompt * rng.uniform(0.6, 1.4))
        eng.submit(Request(f"r{i}", p, gen))
    peak_util = 0.0
    steps = 0
    import time
    t0 = time.perf_counter()
    while eng.step():
        peak_util = max(peak_util, pool.utilization)
        steps += 1
        if steps > 100_000:
            break
    wall = (time.perf_counter() - t0) * 1e6
    return wall, eng.stats, peak_util, pool


def main() -> None:
    for cls, (prompt, gen) in CLASSES.items():
        for policy in ("history", "fixed"):
            # 'fixed' with peak init pages == gg-style peak provisioning
            wall, stats, util, pool = run_policy(policy, prompt, gen)
            name = "adaptive" if policy == "history" else "static_peak"
            row(f"fig11_video/{cls}/{name}", wall / max(stats.decode_steps, 1),
                f"completed={stats.completed};decode_steps={stats.decode_steps};"
                f"peak_util={util:.2f};denials={pool.stats['denials']};"
                f"preempt={stats.preempted}")


if __name__ == "__main__":
    main()
