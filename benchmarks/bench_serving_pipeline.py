"""Paper Figs. 11-13: the video-transcoding pipeline analog, plus the
multi-tenant sharing experiment (§9.3 resource-centric co-location).

Part 1 (fig11_video): three "resolutions" = three request-length classes
(240P/720P/4K -> short/medium/long prompts).  Compare:
  * adaptive (history-sized page grants, continuous batching) vs
  * function-static (every request peak-provisioned, gg/ExCamera style).

Part 2 (fig12_tenancy): the SAME three classes as three serve
Applications co-located on one pod via ``Cluster.submit()``.  Compare:
  * shared  -- one pod-level SharedPagePool, fair-share cross-app
    preemption, per-app history-driven grants; vs
  * private -- each app brings pool_pages/3 of its own (per-function
    peak provisioning of the pool itself).

Part 3 (fig_swa): a sliding-window tenant (reduced gemma3, 5 local : 1
global) serving long generations through the paged backend on the
pod-shared pool.  Compare ring page accounting (local layers hold a
fixed ``ceil(window/PAGE_SIZE)+1``-page ring) against the no-ring arm
(local layers charged like global growing tables).  Emitted as its own
``BENCH_serving_swa.json`` artifact.

Part 4 (fig_alias): the physical-sharing headline -- N same-model paged
tenants on one pod, *aliased* (one pod KVArrayStore, view-local id
remap) vs *private* (``alias_kv=False``: each runner its own pool-sized
arrays, the pre-aliasing behavior).  The metric is LIVE DEVICE KV BYTES
(unique stores summed), not accounted pages: aliasing divides it by N at
token-identical output and equal TTFT.  Emitted as
``BENCH_serving_alias.json``.

Part 5 (fig_prefix): the global prefix cache -- N requests whose prompts
share a >=50% token prefix, served *cached* (refcounted copy-on-write
prefix pages + suffix-only chunked prefill) vs *nocache* (same paged
backend, every prompt prefilled in full) vs *dense* (the token-parity
reference).  Metrics: prefill pages actually computed (the savings
headline), prefix hit rate, COW copies, cache-owned shared pages, and
mean TTFT.  Emitted as ``BENCH_serving_prefix.json``.

Part 6 (fig_obs): observability overhead -- the fig11 null-engine
workload run with the ``repro.obs`` tracer + metrics OFF vs ON
(interleaved off/on pairs, min-over-pairs).  The headline metric is
``overhead_frac`` = min over pairs of (wall_on - wall_off) / wall_off,
gated < a few percent, plus
``lifecycle_ok`` -- the captured trace must reconstruct the exact
request lifecycle (admit/finish/decode-step event counts == the
engine's own counters).  The ON arm also exports its Chrome trace next
to the JSON artifacts so CI uploads a loadable smoke trace.  Emitted as
``BENCH_serving_obs.json``.

Derived: completion wall time, pool utilization, denial/preempt counts.
"""

import argparse
import os
import time

import numpy as np

try:
    from benchmarks.common import (apply_host_settings, emit_json, row,
                                   rows_mark)
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import apply_host_settings, emit_json, row, rows_mark

if __name__ == "__main__":
    # before the repro/jax imports below: the tcmalloc re-exec must
    # happen while it can still take effect (never when imported as a
    # module -- re-execing the host pytest/run.py would be hostile)
    apply_host_settings(reexec=True)
from repro import obs
from repro.core.history import HistoryStore
from repro.runtime import (Application, Cluster, JaxExecutor,
                           NullExecutor, ServeOptions)
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PAGE_SIZE, PagePool, Request

CLASSES = {"240p": (64, 16), "720p": (512, 64), "4k": (2048, 256)}


def run_policy(policy: str, prompt: int, gen: int, n: int = 64):
    hist = HistoryStore()
    if policy == "history":
        for _ in range(40):
            hist.observe("serve", "request", "pages",
                         -(-(prompt + gen) // PAGE_SIZE))
    pool = PagePool(512, history=hist, policy=policy,
                    fixed_init_pages=-(-(2048 + 256) // PAGE_SIZE))  # peak
    eng = ServingEngine(pool, max_batch=16)
    rng = np.random.default_rng(0)
    for i in range(n):
        p = int(prompt * rng.uniform(0.6, 1.4))
        eng.submit(Request(f"r{i}", p, gen))
    peak_util = 0.0
    steps = 0
    t0 = time.perf_counter()
    while eng.step():
        peak_util = max(peak_util, pool.utilization)
        steps += 1
        if steps > 100_000:
            break
    wall = (time.perf_counter() - t0) * 1e6
    return wall, eng.stats, peak_util, pool


def run_obs(*, n: int = 48, repeats: int = 3):
    """The fig11 720p null-engine workload with the obs plane off vs on.

    The arms are INTERLEAVED (off, on, off, on, ...) and the overhead is
    the minimum over back-to-back pairs: host scheduling jitter (and a
    co-running build on a CI runner) inflates whole stretches of wall
    clock, so a same-pair ratio from the quietest moment is the honest
    floor of what the guard-and-append adds -- min-of-all-off vs
    min-of-all-on would compare samples taken under different load.  The
    ON arm verifies lifecycle reconstruction against the engine's own
    counters and returns the last tracer for export."""
    prompt, gen = CLASSES["720p"]

    def one(tracing):
        tracer = obs.enable() if tracing else None
        if tracing:
            obs.enable_metrics()
        wall, stats, _, _ = run_policy("history", prompt, gen, n=n)
        if tracing:
            lifecycle_ok = int(
                len(tracer.by_name("admit", "request")) == stats.admitted
                and len(tracer.by_name("finish", "request")) == stats.completed
                and len(tracer.by_name("decode_step", "engine"))
                == stats.decode_steps
                and len(tracer.by_name("submit", "request")) == n)
            cap = (tracer, stats, lifecycle_ok)
            obs.disable()
            obs.disable_metrics()
            return wall, stats, cap
        assert obs.trace.TRACER is None      # the OFF arm must be off
        return wall, stats, None

    pairs, cap = [], None
    for _ in range(repeats):
        w_off, stats_off, _ = one(False)
        w_on, stats_on, cap = one(True)
        pairs.append((w_off, w_on))
    overhead = min((on - off) / off for off, on in pairs)
    return (min(p[0] for p in pairs), min(p[1] for p in pairs),
            max(overhead, 0.0), stats_off, stats_on, cap)


def run_zensan(*, n: int = 48, repeats: int = 3):
    """fig_zensan: the same null-engine workload with the shadow-ledger
    sanitizer (repro.analysis.zensan) disabled vs enabled, interleaved
    like run_obs.  Two numbers:

    * ``off_tax_frac`` -- the DISABLED plane's cost.  The hook sites
      cannot be compiled out at runtime, so this is bounded by an A/A
      pair: two back-to-back disabled runs, min pairwise delta.  It
      machine-checks "zero cost when disabled" down to runner noise
      (the committed wall baselines catch absolute regressions of the
      disabled path).
    * ``overhead_frac`` -- the ENABLED sanitizer's tax (ledger
      mirroring on every grant/free/pin plus the per-step conservation
      sweep), min over disabled/enabled pairs.

    The ON arm must observe hook traffic and finish with zero
    violations -- a silent sanitizer would make its tax meaningless."""
    from repro.analysis import zensan

    prompt, gen = CLASSES["720p"]

    def one(enabled):
        prev = zensan.SAN
        san = zensan.enable(strict=True) if enabled else None
        if not enabled:
            zensan._install(None)
        try:
            wall, stats, _, _ = run_policy("history", prompt, gen, n=n)
        finally:
            zensan._install(prev)
        meta = (san.events, len(san.violations)) if san else None
        return wall, stats, meta

    aa_pairs, on_pairs, meta = [], [], None
    for _ in range(repeats):
        w_off1, stats_off, _ = one(False)
        w_off2, _, _ = one(False)
        w_on, stats_on, meta = one(True)
        aa_pairs.append((w_off1, w_off2))
        on_pairs.append((w_off2, w_on))
    off_tax = max(min((b - a) / a for a, b in aa_pairs), 0.0)
    overhead = max(min((on - off) / off for off, on in on_pairs), 0.0)
    w_off = min(min(p) for p in aa_pairs)
    w_on = min(p[1] for p in on_pairs)
    return (w_off, w_on, off_tax, overhead, stats_off, stats_on, meta)


def run_tenancy(shared: bool, n_per_app: int = 32, pool_pages: int = 192,
                max_steps: int = 200_000):
    """Three request-length-class apps on one pod, through the runtime."""
    hist = HistoryStore()
    cluster = Cluster(pods=1, history=hist, executor=NullExecutor(),
                      pool_pages=pool_pages if shared else None)
    handles = {}
    rng = np.random.default_rng(0)
    for cls, (prompt, gen) in CLASSES.items():
        app = Application.serve(
            "tinyllama-1.1b", reduced=True, name=f"app-{cls}",
            serve=ServeOptions(
                max_batch=8, private_pool=not shared,
                pool_pages=(pool_pages if shared
                            else pool_pages // len(CLASSES))))
        h = cluster.submit(app)
        for i in range(n_per_app):
            p = int(prompt * rng.uniform(0.6, 1.4))
            h.submit_request(Request(f"{cls}-r{i}", p, gen))
        handles[cls] = h

    t0 = time.perf_counter()
    peak_util, steps, alive = 0.0, 0, set(CLASSES)
    while alive and steps < max_steps:
        for cls in list(alive):
            if not handles[cls].step()["alive"]:
                alive.discard(cls)
        if shared:
            pool = cluster.pod_pool("pod0")
            peak_util = max(peak_util, pool.utilization)
        else:
            used = sum(h.engine.pool.num_pages * h.engine.pool.utilization
                       for h in handles.values())
            peak_util = max(peak_util, used / pool_pages)
        steps += 1
    wall = (time.perf_counter() - t0) * 1e6
    stats = {cls: handles[cls].serving_stats() for cls in CLASSES}
    for h in handles.values():
        h.release()
    return wall, stats, peak_util


def run_swa(rings: bool, *, n: int = 4, prompt: int = 96, gen: int = 280,
            pool_pages: int = 64, max_steps: int = 5_000):
    """One sliding-window tenant on the pod-shared pool, paged backend.

    ``rings=False`` is the baseline arm: local-attention layers are
    charged growing page tables like global ones (decode stays windowed
    and token-identical -- only the page accounting differs)."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=pool_pages)
    h = cluster.submit(Application.serve(
        "gemma3-12b", reduced=True, name="swa-tenant",
        serve=ServeOptions(max_batch=4, backend="paged", swa_rings=rings,
                           policy="fixed")))
    for i in range(n):
        h.submit_request(Request(f"swa-r{i}", prompt, gen))
    pool = h.engine.pool
    t0 = time.perf_counter()
    peak_util = util_sum = 0.0
    peak_local = steps = 0
    while h.step()["alive"] and steps < max_steps:
        u = pool.utilization
        peak_util = max(peak_util, u)
        util_sum += u
        peak_local = max(peak_local, getattr(pool, "used_local", 0))
        steps += 1
    wall = (time.perf_counter() - t0) * 1e6
    stats = h.serving_stats()
    traces = h.runner.decode_traces
    h.release()
    return (wall, stats, peak_util, util_sum / max(steps, 1), traces,
            peak_local)


def run_alias(alias: bool, *, n_tenants: int = 4, n_req: int = 2,
              prompt: int = 200, gen: int = 16, pool_pages: int = 96,
              max_steps: int = 20_000):
    """N same-model paged tenants on one pod: one aliased device page
    pool (view-local remap) vs per-tenant private arrays."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=pool_pages)
    handles, reqs = [], []
    for t in range(n_tenants):
        h = cluster.submit(Application.serve(
            "tinyllama-1.1b", reduced=True, name=f"alias-t{t}",
            serve=ServeOptions(max_batch=4, backend="paged",
                               policy="fixed", alias_kv=alias)))
        for i in range(n_req):
            r = Request(f"t{t}-r{i}", prompt, gen)
            h.submit_request(r)
            reqs.append(r)
        handles.append(h)
    # live device KV bytes: unique array stores only (aliased tenants
    # share one; the accounted SharedPagePool footprint is identical in
    # both arms -- that is exactly the gap this figure measures)
    stores = {id(h.runner.store): h.runner.store for h in handles}
    live_bytes = sum(s.device_bytes() for s in stores.values())
    t0 = time.perf_counter()
    alive, steps = set(range(n_tenants)), 0
    while alive and steps < max_steps:
        for t in list(alive):
            if not handles[t].step()["alive"]:
                alive.discard(t)
        steps += 1
    wall = (time.perf_counter() - t0) * 1e6
    stats = [h.serving_stats() for h in handles]
    tokens = {r.req_id: tuple(r.output_tokens) for r in reqs
              if r.output_tokens is not None}
    for h in handles:
        h.release()
    return live_bytes, len(stores), tokens, stats, wall


def run_router(replicas: int, *, n: int = 12, prompt: int = 64, gen: int = 8,
               pool_pages: int = 96, max_steps: int = 20_000):
    """fig_router: one paged tenant serving a fixed closed-loop request
    set through the front-end router, 1 vs N engine replicas.

    The replicas share the pod pool and ONE device KV array set, so the
    scheduling-level speedup is measured in ROUTER ROUNDS (each round
    dispatches + steps every replica): tokens per round must scale with
    the replica count, and per-request TTFT in rounds must not get
    worse.  Wall time is reported but never gated -- on a single host
    the replica steps serialize, which is exactly why the honest metric
    here is rounds, the simulation's logical clock."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=pool_pages)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name=f"router-x{replicas}",
        serve=ServeOptions(max_batch=2, backend="paged", policy="fixed",
                           replicas=replicas, pool_pages=pool_pages,
                           cache_len=512)))
    rng = np.random.default_rng(3)
    reqs = [Request(f"rt-r{i}", int(prompt * rng.uniform(0.7, 1.3)), gen)
            for i in range(n)]
    for r in reqs:
        h.submit_request(r)
    pending = {r.req_id: r for r in reqs}
    ttft_rounds, rounds = {}, 0
    t0 = time.perf_counter()
    while h.step()["alive"] and rounds < max_steps:
        rounds += 1
        for rid, r in list(pending.items()):
            if r.output_tokens:
                ttft_rounds[rid] = rounds
                del pending[rid]
    wall = (time.perf_counter() - t0) * 1e6
    stats = h.serving_stats()
    tokens = {r.req_id: tuple(r.output_tokens) for r in reqs
              if r.output_tokens is not None}
    h.release()
    return wall, rounds, ttft_rounds, stats, tokens


def _p95(values):
    vals = sorted(values)
    return vals[int(0.95 * (len(vals) - 1))] if vals else 0.0


def _prefix_requests(n: int, overlap: float, prompt: int, gen: int,
                     vocab: int = 100):
    """N requests whose prompts share the first ``overlap`` fraction of
    tokens (explicit ``prompt_tokens``: the bench controls overlap, not
    the req-id synthesizer)."""
    rng = np.random.default_rng(7)
    shared = tuple(int(x) for x in rng.integers(0, vocab,
                                                int(prompt * overlap)))
    reqs = []
    for i in range(n):
        sfx = np.random.default_rng(1000 + i).integers(
            0, vocab, prompt - len(shared))
        toks = shared + tuple(int(x) for x in sfx)
        reqs.append(Request(f"px-r{i}", len(toks), gen, prompt_tokens=toks))
    return reqs


def run_prefix(arm: str, *, n: int = 8, overlap: float = 0.8,
               prompt: int = 2 * PAGE_SIZE + 96, gen: int = 8,
               pool_pages: int = 96, max_steps: int = 20_000):
    """One tenant serving N >=50%-overlapping prompts.  Arms: ``cached``
    (prefix cache on), ``nocache`` (same paged backend, full prefill),
    ``dense`` (the token-parity reference).

    Two phases: the first TWO requests run to completion as the warm-up
    (cold insert + first hit, which also pays every jit trace), then the
    remaining load is measured with windowed stats -- so the TTFT
    comparison is the steady state, not the compile storm.  The prompt
    deliberately ends mid-page and the overlap point falls inside a
    page, so the copy-on-write path (partial-page divergence) is
    exercised, not just whole-page reuse."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=pool_pages)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name=f"prefix-{arm}",
        serve=ServeOptions(
            max_batch=4,
            backend="dense" if arm == "dense" else "paged",
            policy="fixed", cache_len=1024,
            prefix_cache=arm == "cached")))
    reqs = _prefix_requests(n, overlap, prompt, gen)

    def drive():
        steps = 0
        while h.step()["alive"] and steps < max_steps:
            steps += 1

    for r in reqs[:2]:
        # sequential on purpose: concurrent warm-up requests would race
        # the first insert (both miss); one completed cold request plus
        # one completed hit covers every jit trace of both paths
        h.submit_request(r)
        drive()
    snap = h.serving_stats()
    for r in reqs[2:]:
        h.submit_request(r)
    t0 = time.perf_counter()
    drive()
    wall = (time.perf_counter() - t0) * 1e6
    win = h.serving_stats(since=snap)
    stats = h.serving_stats()
    tokens = {r.req_id: tuple(r.output_tokens) for r in reqs
              if r.output_tokens is not None}
    h.release()
    return wall, stats, win, tokens


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per class (fig11) / per app (fig12)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters for CI drift detection")
    args = ap.parse_args()
    n = 6 if args.smoke else args.requests

    for cls, (prompt, gen) in CLASSES.items():
        for policy in ("history", "fixed"):
            # 'fixed' with peak init pages == gg-style peak provisioning
            wall, stats, util, pool = run_policy(policy, prompt, gen, n=n)
            name = "adaptive" if policy == "history" else "static_peak"
            row(f"fig11_video/{cls}/{name}", wall / max(stats.decode_steps, 1),
                f"completed={stats.completed};decode_steps={stats.decode_steps};"
                f"peak_util={util:.2f};denials={pool.stats['denials']};"
                f"preempt={stats.preempted}")

    n_mt = 4 if args.smoke else max(args.requests // 2, 8)
    for mode in ("shared", "private"):
        wall, stats, util = run_tenancy(mode == "shared", n_per_app=n_mt)
        done = sum(s["completed"] for s in stats.values())
        preempt = sum(s["preempted"] for s in stats.values())
        denials = sum(s["pool"]["denials"] for s in stats.values())
        per_app = ";".join(
            f"{cls}:done={s['completed']},preempt={s['preempted']}"
            for cls, s in stats.items())
        row(f"fig12_tenancy/{mode}", wall,
            f"completed={done};peak_util={util:.2f};preempt={preempt};"
            f"denials={denials};{per_app}")
    emit_json("serving_pipeline", extra={"smoke": args.smoke})

    # Part 3: sliding-window ring pages on the pod-shared pool, emitted
    # as its own artifact (BENCH_serving_swa.json)
    # generation must outgrow the ring space (ring_pages * PAGE_SIZE =
    # 256 tokens at the reduced window) for the ring's bounded footprint
    # to show: total length 96 + gen spans 4-5 global pages
    mark = rows_mark()
    gen = 300 if args.smoke else 420
    for rings in (True, False):
        wall, stats, peak, mean, traces, peak_local = run_swa(
            rings, n=4, gen=gen)
        name = "ring" if rings else "no_ring"
        row(f"fig_swa/{name}", wall / max(stats["decode_steps"], 1),
            f"completed={stats['completed']};peak_util={peak:.3f};"
            f"mean_util={mean:.3f};peak_local_pages={peak_local};"
            f"decode_compiles={traces}")
    emit_json("serving_swa", extra={"smoke": args.smoke, "gen": gen},
              rows_from=mark)

    # Part 4: physically shared KV -- live device bytes, 4 same-model
    # tenants, aliased vs private arrays (BENCH_serving_alias.json)
    mark = rows_mark()
    res = {}
    n_req = 2 if args.smoke else 4
    gen_a = 16 if args.smoke else 48
    for arm, alias in (("aliased", True), ("private", False)):
        live, n_stores, toks, stats, wall = run_alias(
            alias, n_req=n_req, gen=gen_a)
        res[arm] = (live, toks)
        done = sum(s["completed"] for s in stats)
        ttft = (sum(s["ttft_s_sum"] for s in stats)
                / max(sum(s["ttft_count"] for s in stats), 1))
        row(f"fig_alias/{arm}", wall,
            f"completed={done};live_kv_mb={live / 2**20:.2f};"
            f"kv_stores={n_stores};mean_ttft_us={ttft * 1e6:.0f}")
    ratio = res["private"][0] / max(res["aliased"][0], 1)
    parity = int(res["private"][1] == res["aliased"][1]
                 and len(res["aliased"][1]) > 0)
    row("fig_alias/savings", 0.0,
        f"kv_bytes_ratio={ratio:.2f};token_parity={parity};"
        f"live_kv_saved={1 - 1 / max(ratio, 1e-9):.1%}")
    emit_json("serving_alias", extra={"smoke": args.smoke, "n_req": n_req,
                                      "gen": gen_a}, rows_from=mark)

    # Part 5: global prefix cache -- prefill-page savings + TTFT at
    # >=50% prompt overlap, token-exact across cached / nocache / dense
    # (BENCH_serving_prefix.json)
    mark = rows_mark()
    n_px = 6 if args.smoke else 12
    overlap = 0.8
    res_px = {}
    for arm in ("cached", "nocache", "dense"):
        wall, stats, win, toks = run_prefix(arm, n=n_px, overlap=overlap)
        res_px[arm] = (stats, win, toks)
        derived = (f"completed={stats['completed']};"
                   f"mean_ttft_us={win['mean_ttft_s'] * 1e6:.0f}")
        if "prefill_pages_computed" in stats:
            derived += f";prefill_pages={stats['prefill_pages_computed']}"
        if arm == "cached":
            derived += (f";prefix_hit_rate={stats['prefix_hit_rate']:.3f};"
                        f"cow_copies={stats['cow_copies']};"
                        f"shared_pages={stats['shared_pages']}")
        row(f"fig_prefix/{arm}", wall, derived)
    cached_pg = res_px["cached"][0]["prefill_pages_computed"]
    nocache_pg = res_px["nocache"][0]["prefill_pages_computed"]
    parity = int(res_px["cached"][2] == res_px["nocache"][2]
                 == res_px["dense"][2] and len(res_px["cached"][2]) > 0)
    ttft = {a: res_px[a][1]["mean_ttft_s"] for a in res_px}
    row("fig_prefix/savings", 0.0,
        f"prefill_page_saved_frac={1 - cached_pg / max(nocache_pg, 1):.3f};"
        f"token_parity={parity};"
        f"ttft_speedup={ttft['nocache'] / max(ttft['cached'], 1e-9):.2f}")
    emit_json("serving_prefix",
              extra={"smoke": args.smoke, "n": n_px, "overlap": overlap},
              rows_from=mark)

    # Part 5b: replica-scaled data plane -- 1 vs 3 engine replicas behind
    # the front-end router, same closed-loop request set, tokens-per-
    # router-round throughput at token parity (BENCH_serving_router.json)
    mark = rows_mark()
    n_rt = 8 if args.smoke else 16
    gen_rt = 8 if args.smoke else 16
    res_rt = {}
    for nrep in (1, 3):
        wall, rounds, ttfts, stats, toks = run_router(
            nrep, n=n_rt, gen=gen_rt)
        res_rt[nrep] = (rounds, ttfts, stats, toks)
        rstats = stats.get("router", {})
        row(f"fig_router/x{nrep}", wall,
            f"completed={stats['completed']};rounds={rounds};"
            f"tokens_per_round="
            f"{stats['tokens_generated'] / max(rounds, 1):.2f};"
            f"ttft_ticks_p95={_p95(ttfts.values()):.0f};"
            f"dispatched={rstats.get('dispatched', 0)}")
    thr = {nrep: res_rt[nrep][2]["tokens_generated"]
           / max(res_rt[nrep][0], 1) for nrep in res_rt}
    p95_1, p95_3 = (_p95(res_rt[1][1].values()),
                    _p95(res_rt[3][1].values()))
    parity = int(res_rt[1][3] == res_rt[3][3] and len(res_rt[1][3]) > 0)
    row("fig_router/scaling", 0.0,
        f"router_speedup={thr[3] / max(thr[1], 1e-9):.2f};"
        f"token_parity={parity};"
        f"ttft_p95_ok={int(p95_3 <= p95_1)}")
    emit_json("serving_router",
              extra={"smoke": args.smoke, "n": n_rt, "gen": gen_rt},
              rows_from=mark)

    # Part 6: observability overhead -- tracer+metrics off vs on over the
    # same null-engine workload, interleaved pairs (BENCH_serving_obs.json)
    mark = rows_mark()
    n_obs = 24 if args.smoke else 96
    rep = 5 if args.smoke else 3
    run_obs(n=n_obs, repeats=1)          # warm-up (first-touch costs)
    w_off, w_on, overhead, stats_off, stats_on, cap = run_obs(
        n=n_obs, repeats=rep)
    tracer, _, lifecycle_ok = cap
    row("fig_obs/off", w_off,
        f"completed={stats_off.completed};"
        f"decode_steps={stats_off.decode_steps}")
    row("fig_obs/on", w_on,
        f"completed={stats_on.completed};"
        f"decode_steps={stats_on.decode_steps};"
        f"events={len(tracer)};dropped={tracer.dropped};"
        f"lifecycle_ok={lifecycle_ok}")
    row("fig_obs/overhead", 0.0,
        f"overhead_frac={overhead:.4f};"
        f"lifecycle_ok={lifecycle_ok};events={len(tracer)}")
    out_dir = os.environ.get("BENCH_ARTIFACT_DIR", "artifacts/bench")
    os.makedirs(out_dir, exist_ok=True)
    trace_path = os.path.join(out_dir, "TRACE_serving_obs.json")
    obs.write_chrome_trace(cap[0], trace_path,
                           extra_meta={"bench": "fig_obs", "n": n_obs})
    print(f"[artifact] {trace_path}", flush=True)
    emit_json("serving_obs",
              extra={"smoke": args.smoke, "n": n_obs, "repeats": rep},
              rows_from=mark)

    # Part 7: zensan sanitizer tax -- disabled A/A noise bound + enabled
    # ledger/sweep overhead over the same null-engine workload
    # (BENCH_serving_zensan.json).  zensan_active=1 asserts the ON arm
    # actually saw hook traffic AND flagged nothing (gated exact).
    mark = rows_mark()
    n_zs = 24 if args.smoke else 96
    rep = 5 if args.smoke else 3
    run_zensan(n=n_zs, repeats=1)        # warm-up (first-touch costs)
    (w_off, w_on, off_tax, zs_over,
     stats_off, stats_on, zs_meta) = run_zensan(n=n_zs, repeats=rep)
    zs_events, zs_viol = zs_meta
    row("fig_zensan/off", w_off,
        f"completed={stats_off.completed};"
        f"decode_steps={stats_off.decode_steps};"
        f"zensan_off_tax_frac={off_tax:.4f}")
    row("fig_zensan/on", w_on,
        f"completed={stats_on.completed};"
        f"decode_steps={stats_on.decode_steps};"
        f"events={zs_events};"
        f"zensan_active={int(zs_events > 0 and zs_viol == 0)};"
        f"zensan_overhead_frac={zs_over:.4f}")
    emit_json("serving_zensan",
              extra={"smoke": args.smoke, "n": n_zs, "repeats": rep},
              rows_from=mark)


if __name__ == "__main__":
    main()
