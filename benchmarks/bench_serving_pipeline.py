"""Paper Figs. 11-13: the video-transcoding pipeline analog, plus the
multi-tenant sharing experiment (§9.3 resource-centric co-location).

Part 1 (fig11_video): three "resolutions" = three request-length classes
(240P/720P/4K -> short/medium/long prompts).  Compare:
  * adaptive (history-sized page grants, continuous batching) vs
  * function-static (every request peak-provisioned, gg/ExCamera style).

Part 2 (fig12_tenancy): the SAME three classes as three serve
Applications co-located on one pod via ``Cluster.submit()``.  Compare:
  * shared  -- one pod-level SharedPagePool, fair-share cross-app
    preemption, per-app history-driven grants; vs
  * private -- each app brings pool_pages/3 of its own (per-function
    peak provisioning of the pool itself).

Derived: completion wall time, pool utilization, denial/preempt counts.
"""

import argparse
import time

import numpy as np

from benchmarks.common import emit_json, row
from repro.core.history import HistoryStore
from repro.runtime import Application, Cluster, NullExecutor
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PAGE_SIZE, PagePool, Request

CLASSES = {"240p": (64, 16), "720p": (512, 64), "4k": (2048, 256)}


def run_policy(policy: str, prompt: int, gen: int, n: int = 64):
    hist = HistoryStore()
    if policy == "history":
        for _ in range(40):
            hist.observe("serve", "request", "pages",
                         -(-(prompt + gen) // PAGE_SIZE))
    pool = PagePool(512, history=hist, policy=policy,
                    fixed_init_pages=-(-(2048 + 256) // PAGE_SIZE))  # peak
    eng = ServingEngine(pool, max_batch=16)
    rng = np.random.default_rng(0)
    for i in range(n):
        p = int(prompt * rng.uniform(0.6, 1.4))
        eng.submit(Request(f"r{i}", p, gen))
    peak_util = 0.0
    steps = 0
    t0 = time.perf_counter()
    while eng.step():
        peak_util = max(peak_util, pool.utilization)
        steps += 1
        if steps > 100_000:
            break
    wall = (time.perf_counter() - t0) * 1e6
    return wall, eng.stats, peak_util, pool


def run_tenancy(shared: bool, n_per_app: int = 32, pool_pages: int = 192,
                max_steps: int = 200_000):
    """Three request-length-class apps on one pod, through the runtime."""
    hist = HistoryStore()
    cluster = Cluster(pods=1, history=hist, executor=NullExecutor(),
                      pool_pages=pool_pages if shared else None)
    handles = {}
    rng = np.random.default_rng(0)
    for cls, (prompt, gen) in CLASSES.items():
        app = Application.serve(
            "tinyllama-1.1b", reduced=True, name=f"app-{cls}",
            max_batch=8, private_pool=not shared,
            pool_pages=pool_pages if shared else pool_pages // len(CLASSES))
        h = cluster.submit(app)
        for i in range(n_per_app):
            p = int(prompt * rng.uniform(0.6, 1.4))
            h.submit_request(Request(f"{cls}-r{i}", p, gen))
        handles[cls] = h

    t0 = time.perf_counter()
    peak_util, steps, alive = 0.0, 0, set(CLASSES)
    while alive and steps < max_steps:
        for cls in list(alive):
            if not handles[cls].step()["alive"]:
                alive.discard(cls)
        if shared:
            pool = cluster.pod_pool("pod0")
            peak_util = max(peak_util, pool.utilization)
        else:
            used = sum(h.engine.pool.num_pages * h.engine.pool.utilization
                       for h in handles.values())
            peak_util = max(peak_util, used / pool_pages)
        steps += 1
    wall = (time.perf_counter() - t0) * 1e6
    stats = {cls: handles[cls].serving_stats() for cls in CLASSES}
    for h in handles.values():
        h.release()
    return wall, stats, peak_util


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per class (fig11) / per app (fig12)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters for CI drift detection")
    args = ap.parse_args()
    n = 6 if args.smoke else args.requests

    for cls, (prompt, gen) in CLASSES.items():
        for policy in ("history", "fixed"):
            # 'fixed' with peak init pages == gg-style peak provisioning
            wall, stats, util, pool = run_policy(policy, prompt, gen, n=n)
            name = "adaptive" if policy == "history" else "static_peak"
            row(f"fig11_video/{cls}/{name}", wall / max(stats.decode_steps, 1),
                f"completed={stats.completed};decode_steps={stats.decode_steps};"
                f"peak_util={util:.2f};denials={pool.stats['denials']};"
                f"preempt={stats.preempted}")

    n_mt = 4 if args.smoke else max(args.requests // 2, 8)
    for mode in ("shared", "private"):
        wall, stats, util = run_tenancy(mode == "shared", n_per_app=n_mt)
        done = sum(s["completed"] for s in stats.values())
        preempt = sum(s["preempted"] for s in stats.values())
        denials = sum(s["pool"]["denials"] for s in stats.values())
        per_app = ";".join(
            f"{cls}:done={s['completed']},preempt={s['preempted']}"
            for cls, s in stats.items())
        row(f"fig12_tenancy/{mode}", wall,
            f"completed={done};peak_util={util:.2f};preempt={preempt};"
            f"denials={denials};{per_app}")
    emit_json("serving_pipeline", extra={"smoke": args.smoke})


if __name__ == "__main__":
    main()
