"""Kernel microbenchmarks: Pallas (interpret mode on CPU -- correctness
path; TPU timings require hardware) vs the jnp reference, small shapes.

Derived: max-abs error vs the oracle (the deployable signal from CPU)."""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import block, row, timeit
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import block, row, timeit
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def ra(*s, scale=1.0):
    return jnp.asarray(RNG.standard_normal(s) * scale, jnp.float32)


def main() -> None:
    # flash attention
    q, k, v = ra(1, 4, 256, 64), ra(1, 2, 256, 64), ra(1, 2, 256, 64)
    f_kern = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, True, 0,
                                                         128, 128))
    f_ref = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v,
                                                            causal=True))
    err = float(jnp.max(jnp.abs(f_kern(q, k, v) - f_ref(q, k, v))))
    us = timeit(lambda: block(f_ref(q, k, v)), iters=5)
    row("kernel/flash_attention_ref_b1h4s256", us, f"kernel_err={err:.2e}")

    # decode attention
    q1, k1, v1 = ra(4, 8, 64), ra(4, 2, 1024, 64), ra(4, 2, 1024, 64)
    vl = jnp.asarray(1024, jnp.int32)
    d_kern = jax.jit(lambda a, b, c: ops.decode_attention(a, b, c, vl))
    d_ref = jax.jit(lambda a, b, c: ref.decode_attention_ref(a, b, c, vl))
    err = float(jnp.max(jnp.abs(d_kern(q1, k1, v1) - d_ref(q1, k1, v1))))
    us = timeit(lambda: block(d_ref(q1, k1, v1)), iters=10)
    row("kernel/decode_attention_ref_b4s1024", us, f"kernel_err={err:.2e}")

    # rwkv6
    r, k2, v2 = ra(1, 4, 256, 32, scale=.5), ra(1, 4, 256, 32, scale=.5), \
        ra(1, 4, 256, 32, scale=.5)
    lw = -jnp.exp(ra(1, 4, 256, 32, scale=.5) - 1)
    u = ra(4, 32, scale=.3)
    # chunk 32: beyond ~32 steps the pairwise-decay exponent range
    # exceeds fp32 headroom at this decay scale (documented saturation
    # limit, DESIGN.md §7) -- tests/test_kernels.py sweeps chunks 16-32
    kk = jax.jit(lambda *a: ops.rwkv6_wkv(*a, chunk=32)[0])
    rr = jax.jit(lambda *a: ref.rwkv6_wkv_ref(*a)[0])
    err = float(jnp.max(jnp.abs(kk(r, k2, v2, lw, u) - rr(r, k2, v2, lw, u))))
    us = timeit(lambda: block(rr(r, k2, v2, lw, u)), iters=3)
    row("kernel/rwkv6_wkv_ref_s256", us, f"kernel_err={err:.2e}")

    # ssd
    x = ra(1, 4, 256, 16, scale=.5)
    dt = jnp.abs(ra(1, 4, 256, scale=.3)) + .1
    a = -jnp.abs(ra(1, 4, 256, scale=.3)) * dt
    b, c = ra(1, 256, 8, scale=.5), ra(1, 256, 8, scale=.5)
    sk = jax.jit(lambda *t: ops.ssd_scan(*t, chunk=64)[0])
    sr = jax.jit(lambda *t: ref.ssd_ref(*t)[0])
    err = float(jnp.max(jnp.abs(sk(x, dt, a, b, c) - sr(x, dt, a, b, c))))
    us = timeit(lambda: block(sr(x, dt, a, b, c)), iters=3)
    row("kernel/ssd_scan_ref_s256", us, f"kernel_err={err:.2e}")

    # rmsnorm
    xx, g = ra(512, 512), ra(512, scale=.1)
    nk = jax.jit(lambda a, b: ops.rmsnorm(a, b))
    nr = jax.jit(lambda a, b: ref.rmsnorm_ref(a, b))
    err = float(jnp.max(jnp.abs(nk(xx, g) - nr(xx, g))))
    us = timeit(lambda: block(nr(xx, g)), iters=10)
    row("kernel/rmsnorm_ref_512x512", us, f"kernel_err={err:.2e}")


if __name__ == "__main__":
    main()
