"""Paper Figs. 15-17 + 27-28: the logistic-regression / small-function
comparison -- real measured steps on CPU with a small model.

Systems compared (paper's OpenWhisk / FastSwap / StepFunctions analogs):
  * zenix_adaptive : materialized plan (remat/microbatch as the ladder says)
  * peak_monolith  : remat none (holds everything; the one-big-function)
  * stage_isolated : microbatch=4 without accumulation fusion analog --
                     modelled by remat='full' + microbatch=4 (pays
                     recompute/"serialization" between stages)

Derived: measured step wall time + working-set estimate.
"""

import jax

try:
    from benchmarks.common import block, row, timeit
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import block, row, timeit
from repro.configs import get_config
from repro.core.materializer import SINGLE_POD, Plan, estimate_bytes_per_device
from repro.configs.base import ShapeConfig
from repro.models import ImplConfig, build_model
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step


def reduced(cfg):
    return cfg.scaled(num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
                      head_dim=32, d_ff=256, vocab_size=512)


def main() -> None:
    cfg = reduced(get_config("tinyllama-1.1b"))
    shape = ShapeConfig("small", "train", 64, 8)
    rng = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(rng, (8, 64), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (8, 64), 0, cfg.vocab_size)}

    plans = {
        "zenix_adaptive": Plan("t", "small", SINGLE_POD, remat="none",
                               microbatch=1, zero=True),
        "peak_monolith": Plan("t", "small", SINGLE_POD, remat="none",
                              microbatch=1, zero=False),
        "stage_isolated": Plan("t", "small", SINGLE_POD, remat="full",
                               microbatch=4, zero=False),
    }
    for name, plan in plans.items():
        model = build_model(cfg, ImplConfig(remat=plan.remat))
        params = model.init_params(rng)
        opt_state = opt.init_opt_state(params)
        step = jax.jit(make_train_step(model, plan))
        p, o, m = step(params, opt_state, batch)  # compile+warm
        def run():
            nonlocal p, o
            p, o, mm = step(p, o, batch)
            block(mm["loss"])
        us = timeit(run, warmup=1, iters=5)
        est = estimate_bytes_per_device(cfg, shape, plan)
        row(f"fig15_small_jobs/{name}", us,
            f"est_state={est/1e6:.1f}MB;remat={plan.remat};mb={plan.microbatch}")


if __name__ == "__main__":
    main()
