"""Paper Fig. 23 + cold/warm table: environment-startup hiding.

BulkX hides RDMA-QP setup behind code loading and caches compilations per
component layout.  TPU analog: XLA compilation is the startup cost; the
compile cache + background prewarm hide it.

Measured for a small (but real, jitted+sharded-shape) step:
  * cold        : full lower+compile on the critical path
  * warm_cache  : layout-keyed cache hit
  * prewarmed   : compile overlapped with "current component running"
                  (background thread), critical path = cache wait only

Derived: critical-path milliseconds (paper reports 773ms -> 284ms -> 10ms
warm; shape differs, the ORDERING is the reproduced claim)."""

import time

import jax
import jax.numpy as jnp

try:
    from benchmarks.common import row
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import row
from repro.core.compile_cache import CompileCache, plan_layout_key
from repro.core.materializer import SINGLE_POD, Plan


def _build_fn(width):
    def build():
        def f(x, w):
            for _ in range(4):
                x = jnp.tanh(x @ w)
            return x.sum()
        return jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, width), jnp.float32),
            jax.ShapeDtypeStruct((width, width), jnp.float32)).compile()
    return build


def main() -> None:
    cc = CompileCache()
    plan = Plan("bench", "train", SINGLE_POD)

    # cold
    key1 = plan_layout_key("bench", "s", "m", plan) + "/w256"
    t0 = time.perf_counter()
    cc.get_or_compile(key1, _build_fn(256))
    cold_ms = (time.perf_counter() - t0) * 1e3

    # warm cache hit
    t0 = time.perf_counter()
    cc.get_or_compile(key1, _build_fn(256))
    warm_ms = (time.perf_counter() - t0) * 1e3

    # prewarmed: background compile overlaps 'current component running'
    key2 = key1 + "/next"
    th = cc.prewarm(key2, _build_fn(384))
    time.sleep(0.9)        # current component executes meanwhile
    t0 = time.perf_counter()
    cc.get_or_compile(key2, _build_fn(384))
    pre_ms = (time.perf_counter() - t0) * 1e3
    th.join(timeout=10)

    row("fig23_startup/cold", cold_ms * 1e3, f"critical_path={cold_ms:.1f}ms")
    row("fig23_startup/warm_cache", warm_ms * 1e3,
        f"critical_path={warm_ms:.2f}ms")
    row("fig23_startup/prewarmed", pre_ms * 1e3,
        f"critical_path={pre_ms:.2f}ms;hidden_behind_exec=True")
    assert warm_ms < cold_ms and pre_ms < cold_ms


if __name__ == "__main__":
    main()
