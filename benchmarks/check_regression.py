"""CI perf-regression gate (bench-gate): diff smoke-run ``BENCH_*.json``
artifacts against the committed baselines in ``benchmarks/baselines/``.

Every benchmark row's ``derived`` string is a ``k=v;k=v`` record; the
gate parses both sides and applies per-metric tolerance rules:

* **exact** -- determinism proxies (completion counts, token parity,
  store counts): any change fails.
* **higher_worse / lower_worse** -- capacity and latency proxies (pool
  utilization, TTFT in ticks, compile counts, the aliasing bytes ratio,
  savings fractions): fail past a relative tolerance (default 25%) plus
  a small absolute slack so near-zero baselines don't amplify noise.
* everything else -- including ALL wall-clock metrics (``us_per_call``,
  ``*_us``): reported as info only.  CI runners are far too noisy to
  gate on microseconds; the gate covers the metrics that are functions
  of the allocator/scheduler decisions, which are deterministic at
  smoke scale.

Run locally after a smoke pass::

    PYTHONPATH=src python benchmarks/bench_serving_pipeline.py --smoke
    python benchmarks/check_regression.py            # diff vs baselines
    python benchmarks/check_regression.py --update   # refresh baselines

Exit status 1 on any FAIL (regression, missing artifact, or missing
baseline row) -- the CI bench-smoke job runs this after the smoke
benchmarks, so a perf regression in the gated proxies blocks the PR.

Stdlib-only on purpose: runs in any job without the jax stack.
"""

import argparse
import json
import os
import shutil
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINES = os.path.join(HERE, "baselines")
DEFAULT_CURRENT = os.environ.get("BENCH_ARTIFACT_DIR", "artifacts/bench")

#: rel_tol is the allowed fractional move in the WORSE direction;
#: abs_slack is added on top (|delta| <= base*rel_tol + abs_slack passes).
EXACT = ("completed", "token_parity", "tokens_match", "finished",
         "restored", "kv_stores", "lifecycle_ok", "zensan_active",
         "ttft_p95_ok")


def rule_for(metric: str):
    """(kind, rel_tol, abs_slack) for a metric name, or None (info-only)."""
    if metric in EXACT:
        return ("exact", 0.0, 0.0)
    if metric.endswith("_us") or metric == "us_per_call":
        return None                       # wall clock: never gated
    if "util" in metric:
        return ("higher_worse", 0.25, 0.02)
    if "ttft_ticks" in metric:
        return ("higher_worse", 0.25, 0.05)
    if metric in ("decode_compiles", "peak_local_pages"):
        return ("higher_worse", 0.0, 1.0)
    if metric == "overhead_frac":
        # observability tax: min over interleaved off/on pairs, so one
        # quiet pair suffices even on a loaded runner -- but it is still
        # a timing, so allow generous relative drift plus an absolute
        # slack that keeps the gate at the <5% overhead ceiling
        return ("higher_worse", 1.0, 0.05)
    if metric == "zensan_off_tax_frac":
        # zero-cost-when-disabled, machine-checked: min over interleaved
        # disabled/disabled pairs bounds the hook plumbing below runner
        # noise.  Baseline is 0.0, so the gate is purely the absolute
        # slack -- the 0% ceiling with a noise allowance.
        return ("higher_worse", 0.0, 0.05)
    if metric == "zensan_overhead_frac":
        # enabled-sanitizer tax (ledger mirroring + per-step sweeps):
        # a timing, so generous drift like overhead_frac above
        return ("higher_worse", 1.0, 0.25)
    if metric == "kv_bytes_ratio":
        return ("lower_worse", 0.25, 0.0)
    if metric == "router_speedup":
        # tokens-per-router-round, 3 replicas vs 1: deterministic at
        # smoke scale (logical clock, not wall time) but allow the same
        # drift budget as the other ratio gates
        return ("lower_worse", 0.25, 0.10)
    if metric == "prefix_hit_rate":
        return ("lower_worse", 0.25, 0.05)
    if metric.endswith("_frac") or "saved" in metric:
        return ("lower_worse", 0.25, 0.10)
    return None


def parse_derived(derived: str):
    """``k=v;k=v`` -> {k: float} (percent strings normalized; non-numeric
    values skipped)."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        v = v.strip().rstrip("%")
        try:
            out[k.strip()] = float(v)
        except ValueError:
            pass
    return out


def load_rows(path: str):
    """-> (rows, smoke_flag).  ``smoke`` comes from the artifact's extra
    dict (None when the bench doesn't record it)."""
    with open(path) as f:
        payload = json.load(f)
    rows = {}
    for r in payload.get("rows", []):
        d = parse_derived(r.get("derived", ""))
        d["us_per_call"] = float(r.get("us_per_call", 0.0))
        rows[r["name"]] = d
    return rows, payload.get("extra", {}).get("smoke")


def check_metric(metric, base, cur):
    """-> (status, note).  status in OK / FAIL / INFO."""
    r = rule_for(metric)
    if r is None:
        return "INFO", ""
    kind, rel, slack = r
    if kind == "exact":
        return ("OK", "") if cur == base else ("FAIL", "must match exactly")
    worse = cur - base if kind == "higher_worse" else base - cur
    allowed = abs(base) * rel + slack
    if worse > allowed:
        return "FAIL", f"worse by {worse:.3g} (allowed {allowed:.3g})"
    return "OK", ""


def compare(baseline_dir: str, current_dir: str) -> int:
    names = sorted(f for f in os.listdir(baseline_dir)
                   if f.startswith("BENCH_") and f.endswith(".json"))
    if not names:
        print(f"no baselines in {baseline_dir}", file=sys.stderr)
        return 1
    failures = 0
    w = (28, 22, 10, 10, 8)
    print(f"{'row':<{w[0]}} {'metric':<{w[1]}} {'base':>{w[2]}} "
          f"{'current':>{w[3]}} {'status':<{w[4]}} note")
    for fname in names:
        cur_path = os.path.join(current_dir, fname)
        print(f"-- {fname}")
        if not os.path.exists(cur_path):
            print(f"   MISSING current artifact {cur_path}")
            failures += 1
            continue
        base_rows, base_smoke = load_rows(os.path.join(baseline_dir, fname))
        cur_rows, cur_smoke = load_rows(cur_path)
        if base_smoke != cur_smoke:
            # a full-scale run diffed against smoke baselines (or vice
            # versa) would fail every EXACT metric with misleading notes
            print(f"   FAIL smoke flag mismatch: baseline smoke="
                  f"{base_smoke} vs current smoke={cur_smoke} -- rerun "
                  "the benchmarks with --smoke")
            failures += 1
            continue
        for row_name, base in base_rows.items():
            cur = cur_rows.get(row_name)
            if cur is None:
                print(f"{row_name:<{w[0]}} {'<row>':<{w[1]}} "
                      f"{'':>{w[2]}} {'':>{w[3]}} {'FAIL':<{w[4]}} "
                      "row missing from current run")
                failures += 1
                continue
            for metric, bval in base.items():
                if metric not in cur:
                    if rule_for(metric) is not None:
                        print(f"{row_name:<{w[0]}} {metric:<{w[1]}} "
                              f"{bval:>{w[2]}.4g} {'--':>{w[3]}} "
                              f"{'FAIL':<{w[4]}} gated metric disappeared")
                        failures += 1
                    continue
                status, note = check_metric(metric, bval, cur[metric])
                if status == "INFO" and bval == cur[metric]:
                    continue              # keep the table readable
                print(f"{row_name:<{w[0]}} {metric:<{w[1]}} "
                      f"{bval:>{w[2]}.4g} {cur[metric]:>{w[3]}.4g} "
                      f"{status:<{w[4]}} {note}")
                if status == "FAIL":
                    failures += 1
    print(f"\nbench-gate: {'FAIL' if failures else 'OK'} "
          f"({failures} regression(s))")
    return 1 if failures else 0


def update(baseline_dir: str, current_dir: str) -> int:
    os.makedirs(baseline_dir, exist_ok=True)
    copied = rc = 0
    for f in sorted(os.listdir(current_dir)):
        if not (f.startswith("BENCH_") and f.endswith(".json")):
            continue
        src = os.path.join(current_dir, f)
        _, smoke = load_rows(src)
        if smoke is False:
            # full-scale artifacts must never become CI smoke baselines
            print(f"REFUSED  {f}: recorded with smoke=False -- rerun the "
                  "benchmark with --smoke before --update", file=sys.stderr)
            rc = 1
            continue
        shutil.copyfile(src, os.path.join(baseline_dir, f))
        print(f"baseline <- {f}")
        copied += 1
    if not copied:
        print(f"no BENCH_*.json under {current_dir}", file=sys.stderr)
        return 1
    return rc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default=DEFAULT_CURRENT,
                    help="directory with the fresh smoke artifacts")
    ap.add_argument("--baselines", default=DEFAULT_BASELINES,
                    help="directory with the committed baselines")
    ap.add_argument("--update", action="store_true",
                    help="copy current artifacts over the baselines")
    args = ap.parse_args()
    if args.update:
        sys.exit(update(args.baselines, args.current))
    sys.exit(compare(args.baselines, args.current))


if __name__ == "__main__":
    main()
