"""Paper Fig. 22 + Fig. 26: sizing strategies on Azure-like workloads.

Four workload classes from the paper's appendix (Small / Large / Varying /
Stable invocation-memory distributions) replayed under three policies:
fixed (256/64 analog), peak-provision, history-LP (§9.3).

Derived: mean utilization + mean completion time (the Fig. 22 axes).
"""

import numpy as np

try:
    from benchmarks.common import row, timeit
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import row, timeit
from repro.core.sizing import (fixed_sizing, peak_sizing, simulate_policy,
                               solve_init_step)

RNG = np.random.default_rng(42)

WORKLOADS = {
    "small": RNG.gamma(2.0, 2.0, 500).clip(1, 40),
    "large": (200 + RNG.gamma(3.0, 20.0, 500)).clip(1, 900),
    "varying": np.exp(RNG.normal(3.0, 1.2, 500)).clip(1, 1200),
    "stable": (64 + RNG.normal(0, 2.0, 500)).clip(32, 96),
}


def main() -> None:
    for wname, usage in WORKLOADS.items():
        hist = [(float(v), 1.0) for v in usage]
        us = timeit(lambda: solve_init_step(hist), iters=3)
        policies = {
            "fixed": fixed_sizing(4.0, 1.0),
            "peak": peak_sizing(hist),
            "history": solve_init_step(hist, cost_factor=0.3,
                                       waste_threshold=0.5),
        }
        for pname, sol in policies.items():
            sim = simulate_policy(usage, sol)
            row(f"fig22_sizing/{wname}/{pname}",
                us if pname == "history" else 0.0,
                f"util={sim['mean_utilization']:.2f};"
                f"time={sim['mean_time']:.1f};"
                f"scaleups={sim['mean_scaleups']:.2f};"
                f"init={sol.init:.0f};step={sol.step:.0f}")


if __name__ == "__main__":
    main()
