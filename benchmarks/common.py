"""Benchmark helpers: timing, CSV row emission, and JSON artifacts.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the figure-specific metric, e.g. %-memory-saved).  Rows are also
buffered so ``emit_json(bench)`` can persist the whole run as
``BENCH_<bench>.json`` under ``artifacts/bench/`` (override with
``$BENCH_ARTIFACT_DIR``) -- the machine-readable record CI uploads, so
the perf trajectory is trackable across PRs instead of living in log
scrollback.  ``benchmarks/check_regression.py`` diffs these artifacts
against the committed baselines in ``benchmarks/baselines/`` (the CI
bench-gate).

Import note: drivers import this module as ``benchmarks.common`` with a
``from common import ...`` fallback, so they run both as scripts
(``PYTHONPATH=src python benchmarks/bench_x.py`` -- only ``benchmarks/``
itself is on ``sys.path``) and as package modules (``run.py``, tests)."""

import ctypes.util
import json
import os
import sys
import time
from typing import Callable, Dict, List, Optional

_ROWS: List[Dict] = []

#: XLA flags for run-to-run stability -- pin the host platform to ONE
#: device (timings must not shard across a variable host core count)
#: and serialize compilation (parallel compile contends with the timed
#: region on CPU hosts).  Set only when the user has not chosen their
#: own $XLA_FLAGS.
_STABLE_XLA_FLAGS = ("--xla_force_host_platform_device_count=1 "
                     "--xla_cpu_parallel_codegen_split_count=1")

_HOST: Optional[Dict] = None


def apply_host_settings(reexec: bool = False) -> Dict:
    """Benchmark host hygiene (the classic TPU-repo ``run.sh`` settings),
    applied ONCE per process and recorded in every artifact it emits.

    * tcmalloc: page-pool churn is allocator-bound on the host side, and
      glibc malloc jitter reads as perf regression noise.  A live process
      cannot retrofit its allocator, so with ``reexec=True`` (bench
      entry points ONLY, before importing jax) the process re-execs
      itself once with ``LD_PRELOAD`` pointing at libtcmalloc when the
      linker cache has one; the default records presence/activity
      without touching the process (``emit_json`` calls from pytest or
      CI wrappers must never re-exec);
    * stable XLA flags: autotuning picks different kernels run-to-run --
      pin the level via ``$XLA_FLAGS`` unless jax is already imported
      (too late) or the caller set their own (their choice wins).

    Idempotent; returns the applied-settings record (also stored in the
    ``host`` key of every ``emit_json`` payload)."""
    global _HOST
    if _HOST is not None:
        return _HOST
    preload = os.environ.get("LD_PRELOAD", "")
    tcmalloc = ctypes.util.find_library("tcmalloc")
    if "jax" in sys.modules:
        xla_applied = False           # too late: jax read $XLA_FLAGS
    else:
        xla_applied = "XLA_FLAGS" not in os.environ
        os.environ.setdefault("XLA_FLAGS", _STABLE_XLA_FLAGS)
        os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    if (reexec and tcmalloc and tcmalloc not in preload
            and "jax" not in sys.modules
            and not os.environ.get("_BENCH_HOST_REEXEC")):
        os.environ["LD_PRELOAD"] = (preload + " " + tcmalloc).strip()
        # no malloc warnings on numpy's big arena reservations
        os.environ.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                              "60000000000")
        os.environ["_BENCH_HOST_REEXEC"] = "1"   # one hop, even if the
        os.execv(sys.executable, [sys.executable] + sys.argv)  # preload
        # fails to take (missing lib would otherwise loop forever)
    _HOST = {
        "tcmalloc": tcmalloc or "",
        "tcmalloc_active": bool(tcmalloc and tcmalloc in preload),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "xla_flags_applied": xla_applied,
    }
    return _HOST


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)
    _ROWS.append({"name": name, "us_per_call": round(us_per_call, 2),
                  "derived": derived})


def rows_mark() -> int:
    """Position marker into the row buffer: pass it to ``emit_json`` as
    ``rows_from`` so one driver script can emit several artifacts, each
    holding only its own scenario's rows."""
    return len(_ROWS)


def emit_json(bench: str, extra: Optional[Dict] = None,
              out_dir: Optional[str] = None, rows_from: int = 0) -> str:
    """Write every ``row()`` since ``rows_from`` (a ``rows_mark()``) to
    ``BENCH_<bench>.json``.  Returns the path.  ``derived`` strings stay
    verbatim (they are already ``k=v;k=v`` records); ``extra`` carries
    bench-level context such as parameters or environment."""
    out_dir = out_dir or os.environ.get("BENCH_ARTIFACT_DIR",
                                        "artifacts/bench")
    os.makedirs(out_dir, exist_ok=True)
    payload = {
        "bench": bench,
        "argv": sys.argv[1:],
        "unix_time": int(time.time()),
        "rows": list(_ROWS[rows_from:]),
        "extra": extra or {},
        "host": apply_host_settings(),
    }
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
    print(f"[artifact] {path}", flush=True)
    return path


def block(x):
    import jax
    return jax.block_until_ready(x)
