"""Benchmark helpers: timing, CSV row emission, and JSON artifacts.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the figure-specific metric, e.g. %-memory-saved).  Rows are also
buffered so ``emit_json(bench)`` can persist the whole run as
``BENCH_<bench>.json`` under ``artifacts/bench/`` (override with
``$BENCH_ARTIFACT_DIR``) -- the machine-readable record CI uploads, so
the perf trajectory is trackable across PRs instead of living in log
scrollback.  ``benchmarks/check_regression.py`` diffs these artifacts
against the committed baselines in ``benchmarks/baselines/`` (the CI
bench-gate).

Import note: drivers import this module as ``benchmarks.common`` with a
``from common import ...`` fallback, so they run both as scripts
(``PYTHONPATH=src python benchmarks/bench_x.py`` -- only ``benchmarks/``
itself is on ``sys.path``) and as package modules (``run.py``, tests)."""

import json
import os
import sys
import time
from typing import Callable, Dict, List, Optional

_ROWS: List[Dict] = []


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)
    _ROWS.append({"name": name, "us_per_call": round(us_per_call, 2),
                  "derived": derived})


def rows_mark() -> int:
    """Position marker into the row buffer: pass it to ``emit_json`` as
    ``rows_from`` so one driver script can emit several artifacts, each
    holding only its own scenario's rows."""
    return len(_ROWS)


def emit_json(bench: str, extra: Optional[Dict] = None,
              out_dir: Optional[str] = None, rows_from: int = 0) -> str:
    """Write every ``row()`` since ``rows_from`` (a ``rows_mark()``) to
    ``BENCH_<bench>.json``.  Returns the path.  ``derived`` strings stay
    verbatim (they are already ``k=v;k=v`` records); ``extra`` carries
    bench-level context such as parameters or environment."""
    out_dir = out_dir or os.environ.get("BENCH_ARTIFACT_DIR",
                                        "artifacts/bench")
    os.makedirs(out_dir, exist_ok=True)
    payload = {
        "bench": bench,
        "argv": sys.argv[1:],
        "unix_time": int(time.time()),
        "rows": list(_ROWS[rows_from:]),
        "extra": extra or {},
    }
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
    print(f"[artifact] {path}", flush=True)
    return path


def block(x):
    import jax
    return jax.block_until_ready(x)
