"""Benchmark helpers: timing + CSV row emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the figure-specific metric, e.g. %-memory-saved)."""

import sys
import time
from typing import Callable, Optional


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def block(x):
    import jax
    return jax.block_until_ready(x)
