"""Autoscale control-plane benchmark: bursty multi-tenant serving,
static quotas vs the `repro.autoscale` feedback loop.

Closed-loop scenario: three serve applications co-located on one pod's
shared KV pool, driven by phased bursty traffic --

* ``hot``  -- bursts on even phases, quiet on odd ones;
* ``warm`` -- bursts on odd phases;
* ``cold`` -- one burst in the first phase, then idle forever (the
  parking candidate).

Arms:

* ``static``     -- every app keeps a fixed ``pool/3`` page quota and
  its submitted byte footprint forever (peak provisioning);
* ``autoscaled`` -- `Cluster.tick()` drives target-tracking scale
  up/down, demand-weighted quota rebalancing, and idle parking; parked
  apps are transparently unparked when their next burst arrives.

Derived metrics: time-integrated provisioned footprint (quota pages and
scheduler bytes -- the paper's "resource consumption"), completion
counts, and TTFT, which autoscaling must hold at-or-better while
shrinking the footprint.  A second section microbenchmarks park/unpark
warm-restart latency on a real (reduced) model with the paged backend.
"""

import argparse
import itertools
import time

import numpy as np

try:
    from benchmarks.common import emit_json, row
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import emit_json, row
from repro.core.history import HistoryStore
from repro.runtime import (Application, Cluster, JaxExecutor,
                           NullExecutor, ServeOptions)
from repro.serving.kv_cache import Request

APPS = ("hot", "warm", "cold")
NUM_PHASES = 4


def arrival_rate(app: str, t: int, phase_len: int) -> int:
    """Requests per tick for one app at tick ``t``.  Offered load is kept
    under the service rate (max_batch x steps_per_tick) so queues drain
    between bursts -- saturation would hide the idle windows autoscaling
    exploits."""
    phase = (t // phase_len) % NUM_PHASES
    if app == "hot":
        return 2 if phase % 2 == 0 else 0
    if app == "warm":
        return 2 if phase % 2 == 1 else 0
    # cold: an opening burst, a long idle stretch (the parking window),
    # then one late burst that exercises the transparent unpark
    return 2 if (t < phase_len or t // phase_len == 7) else 0


def run_arm(autoscale: bool, *, ticks: int, phase_len: int,
            pool_pages: int, steps_per_tick: int = 6):
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=pool_pages)
    if autoscale:
        from repro.autoscale import QuotaRebalancer
        cluster.enable_autoscale(
            idle_park_s=1.5 * phase_len, denial_target_per_s=2.0,
            cooldown_up_s=1.0, cooldown_down_s=max(phase_len / 2, 1.0),
            confirm_ticks=2,
            rebalancer=QuotaRebalancer(headroom=2.0))
    handles = {}
    for name in APPS:
        handles[name] = cluster.submit(Application.serve(
            "tinyllama-1.1b", reduced=True, name=name,
            serve=ServeOptions(max_batch=8,
                               quota_pages=pool_pages // len(APPS))))
    rng = np.random.default_rng(0)
    rid = itertools.count()
    integ = {"quota_pages": 0.0, "used_pages": 0.0, "demand_bytes": 0.0}
    parks = unparks = 0
    inflight = []                        # (request, submit tick)
    ttft_ticks = []                      # logical-clock TTFT per request
    t0 = time.perf_counter()

    def pump(n):
        for _ in range(n):
            for h in handles.values():
                if not h.parked:
                    h.step()

    def harvest(t):
        for req, t_sub in list(inflight):
            if req.first_token_at is not None:
                ttft_ticks.append(t - t_sub)
                inflight.remove((req, t_sub))
            elif req.state == "rejected":
                inflight.remove((req, t_sub))

    for t in range(ticks):
        for name, h in handles.items():
            for _ in range(arrival_rate(name, t, phase_len)):
                was_parked = h.parked
                req = Request(f"{name}-{next(rid)}",
                              int(rng.integers(48, 320)),
                              int(rng.integers(8, 24)))
                h.submit_request(req)
                inflight.append((req, t))
                unparks += was_parked and not h.parked
        # reconcile mid-tick: a quota rebalance triggered by this tick's
        # burst can serve the same tick's arrivals
        pump(steps_per_tick // 2)
        for act in cluster.tick(now=float(t)):
            parks += act["action"] == "park"
        pump(steps_per_tick - steps_per_tick // 2)
        harvest(t)
        pool = cluster.pod_pool("pod0")
        integ["quota_pages"] += sum(
            0 if v.parked else min(v.quota, pool.num_pages)
            for v in pool.views.values())
        integ["used_pages"] += pool.used_pages
        integ["demand_bytes"] += sum(
            h.job.demand_bytes for h in handles.values())
    # drain what's still in flight so completion/TTFT are final
    for _ in range(50_000):
        if not any(h.step()["alive"] for h in handles.values()
                   if not h.parked):
            break
    harvest(ticks)
    wall = (time.perf_counter() - t0) * 1e6
    stats = {n: h.serving_stats() for n, h in handles.items()}
    for h in handles.values():
        h.release()
    summary = {
        "completed": sum(s["completed"] for s in stats.values()),
        "rejected": sum(s["rejected"] for s in stats.values()),
        "preempted": sum(s["preempted"] for s in stats.values()),
        "mean_ttft_ticks": (sum(ttft_ticks) / len(ttft_ticks)
                            if ttft_ticks else 0.0),
        "mean_ttft_us": 1e6 * sum(s["ttft_s_sum"] for s in stats.values())
        / max(sum(s["ttft_count"] for s in stats.values()), 1),
        "mean_quota_pages": integ["quota_pages"] / ticks,
        "mean_used_pages": integ["used_pages"] / ticks,
        "mean_demand_mb": integ["demand_bytes"] / ticks / 2**20,
        "parks": parks,
        "unparks": unparks,
    }
    return wall, summary


def bench_park_warm_restart(smoke: bool):
    """Real-model park/unpark round trip (paged backend): how fast is
    the warm restart, and how much of the footprint does parking free."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0))
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="park-demo",
        serve=ServeOptions(max_batch=4, pool_pages=32, cache_len=512,
                           backend="paged")))
    n = 2 if smoke else 4
    for i in range(n):
        h.submit_request(Request(f"r{i}", 200, 24))
    for _ in range(4):
        h.step()
    bytes_before = h.job.demand_bytes
    pages_before = h.engine.pool.used
    t0 = time.perf_counter()
    receipt = h.park()
    park_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    restore = h.unpark()
    unpark_us = (time.perf_counter() - t0) * 1e6
    stats = h.run(max_steps=10_000)
    h.release()
    page_frac = receipt["freed_pages"] / max(pages_before, 1)
    byte_frac = receipt["freed_bytes"] / max(bytes_before, 1)
    row("autoscale/park_warm_restart_paged", park_us,
        f"unpark_us={unpark_us:.0f};freed_page_frac={page_frac:.2f};"
        f"freed_byte_frac={byte_frac:.2f};"
        f"restored={restore['restored_requests']};"
        f"completed={stats['completed']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=96)
    ap.add_argument("--phase-len", type=int, default=12)
    ap.add_argument("--pool-pages", type=int, default=96)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters for CI drift detection")
    args = ap.parse_args()
    ticks = 48 if args.smoke else args.ticks
    phase_len = 6 if args.smoke else args.phase_len

    results = {}
    for arm, auto in (("static", False), ("autoscaled", True)):
        wall, s = run_arm(auto, ticks=ticks, phase_len=phase_len,
                          pool_pages=args.pool_pages)
        results[arm] = s
        row(f"autoscale/{arm}", wall / ticks,
            f"completed={s['completed']};rejected={s['rejected']};"
            f"preempt={s['preempted']};"
            f"mean_ttft_ticks={s['mean_ttft_ticks']:.3f};"
            f"mean_ttft_us={s['mean_ttft_us']:.0f};"
            f"mean_quota_pages={s['mean_quota_pages']:.1f};"
            f"mean_used_pages={s['mean_used_pages']:.1f};"
            f"mean_demand_mb={s['mean_demand_mb']:.1f};"
            f"parks={s['parks']};unparks={s['unparks']}")
    st, au = results["static"], results["autoscaled"]
    quota_save = 1 - au["mean_quota_pages"] / max(st["mean_quota_pages"], 1e-9)
    bytes_save = 1 - au["mean_demand_mb"] / max(st["mean_demand_mb"], 1e-9)
    dttft = au["mean_ttft_ticks"] - st["mean_ttft_ticks"]
    row("autoscale/savings", 0.0,
        f"quota_pages_saved={quota_save:.1%};"
        f"demand_bytes_saved={bytes_save:.1%};"
        f"ttft_delta_ticks_vs_static={dttft:+.3f}")

    bench_park_warm_restart(args.smoke)
    emit_json("autoscale", extra={"ticks": ticks, "phase_len": phase_len,
                                  "pool_pages": args.pool_pages,
                                  "smoke": args.smoke})


if __name__ == "__main__":
    main()
