"""Paper §6.2 scheduler scalability: 50k invocations/s global, 20k
components/s per rack.  Replays arrival traces through the runtime's REAL
submission path (Cluster.submit / AppHandle.release with a NullExecutor),
so the measured rate includes all per-application bookkeeping.

Derived: scheduling ops/s vs the paper's claimed rates."""

import argparse

try:
    from benchmarks.common import emit_json, row
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import emit_json, row
from repro.runtime import measure_cluster_throughput


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI drift detection")
    args = ap.parse_args()
    grid = (((2_000, 2),) if args.smoke
            else ((20_000, 4), (50_000, 8), (100_000, 16)))
    for n_jobs, pods in grid:
        stats = measure_cluster_throughput(n_jobs=n_jobs, num_pods=pods)
        rate = stats["sched_ops_per_s"]
        row(f"sched_scalability/jobs{n_jobs}_pods{pods}",
            1e6 / max(rate, 1),
            f"ops_per_s={rate:.0f};paper_global=50000;paper_rack=20000;"
            f"finished={stats['finished']}")
    emit_json("scheduler", extra={"smoke": args.smoke})


if __name__ == "__main__":
    main()
