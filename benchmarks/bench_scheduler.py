"""Paper §6.2 scheduler scalability: 50k invocations/s global, 20k
components/s per rack.  Replays arrival traces through the two-level
scheduler (pure decision throughput, like the paper's measurement).

Derived: scheduling ops/s vs the paper's claimed rates."""

from benchmarks.common import row
from repro.core.scheduler import measure_scheduler_throughput


def main() -> None:
    for n_jobs, pods in ((20_000, 4), (50_000, 8), (100_000, 16)):
        stats = measure_scheduler_throughput(n_jobs=n_jobs, num_pods=pods)
        rate = stats["sched_ops_per_s"]
        row(f"sched_scalability/jobs{n_jobs}_pods{pods}",
            1e6 / max(rate, 1),
            f"ops_per_s={rate:.0f};paper_global=50000;paper_rack=20000;"
            f"finished={stats['finished']}")


if __name__ == "__main__":
    main()
