"""Paper Fig. 9 + Fig. 20: execution time, adaptive vs baselines.

CPU container cannot measure TPU wall time; the comparable quantity is the
roofline step-time bound max(compute, memory, collective) from the
compiled dry-run artifacts (§Roofline).  Rows report the bound under the
adaptive plan for each architecture's train cell, plus MODEL_FLOPS-derived
MFU upper bound -- the quantity §Perf hillclimbs.

Derived: bound breakdown + dominant term."""

import glob
import json
import os

try:
    from benchmarks.common import row
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import row

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def main() -> None:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, "*__single_pod.json"))):
        with open(path) as f:
            c = json.load(f)
        if c.get("status") == "ok":
            cells.append(c)
    if not cells:
        row("fig9_exec_time/NO_ARTIFACTS", 0.0,
            "run `python -m repro.launch.dryrun` first")
        return
    for c in cells:
        r = c["roofline"]
        bound = r["step_time_bound_s"]
        row(f"fig9_exec_time/{c['arch']}/{c['shape']}", bound * 1e6,
            f"dom={r['dominant']};cmp={r['compute_term_s']:.3f}s;"
            f"mem={r['memory_term_s']:.3f}s;col={r['collective_term_s']:.3f}s;"
            f"mfu_ub={r['mfu_upper_bound']:.3f}")


if __name__ == "__main__":
    main()
