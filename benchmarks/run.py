"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (deliverable d)."""

import importlib
import os
import sys
import traceback

# runnable as `python benchmarks/run.py` with only src/ on PYTHONPATH:
# the drivers are imported as the `benchmarks` package, which needs the
# repo root importable
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

MODULES = [
    "benchmarks.bench_memory_adaptation",   # Fig 8 / 19
    "benchmarks.bench_exec_time",           # Fig 9 / 20 (roofline bound)
    "benchmarks.bench_ablation",            # Fig 10 / 14
    "benchmarks.bench_serving_pipeline",    # Fig 11-13
    "benchmarks.bench_small_jobs",          # Fig 15-17 / 27-28
    "benchmarks.bench_scaling_methods",     # Fig 18
    "benchmarks.bench_placement",           # Fig 21
    "benchmarks.bench_sizing",              # Fig 22 / 26
    "benchmarks.bench_startup",             # Fig 23 / cold-warm table
    "benchmarks.bench_scheduler",           # §6.2 scheduler scalability
    "benchmarks.bench_kernels",             # kernel validation timings
]


def main() -> None:
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = 0
    for mod in MODULES:
        if only and only not in mod:
            continue
        try:
            importlib.import_module(mod).main()
        except Exception as e:
            failures += 1
            print(f"{mod},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
