"""Paper Fig. 8 + Fig. 19: memory consumption, adaptive vs function-static.

BulkX reduces memory 72-90% against PyWren-style peak provisioning because
a static function DAG sizes every stage for the peak input.  TPU analog:
per-invocation materialized footprint (params+opt+acts under the adapted
plan) vs a static configuration provisioned for the largest input
(longest sequence) and the deepest remat-free residency it must survive.

Derived column: percent memory saved at each input scale.
"""

import dataclasses

try:
    from benchmarks.common import row, timeit
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import row, timeit
from repro.configs import SHAPES, get_config
from repro.core.materializer import (GB, SINGLE_POD,
                                     estimate_bytes_per_device, materialize)


def static_peak_plan(cfg, shape, mesh):
    """Function-DAG analog: one fixed configuration for ALL inputs, sized
    for the peak input (seq 32k) with no adaptive remat/microbatching."""
    peak_shape = dataclasses.replace(shape, seq_len=32_768,
                                     global_batch=shape.global_batch)
    plan = materialize(cfg, peak_shape, mesh)
    # static: no per-invocation adaptation -> keep the peak plan's knobs
    return plan


def main() -> None:
    mesh = SINGLE_POD
    arch = "mistral-nemo-12b"
    cfg = get_config(arch)
    base = SHAPES["train_4k"]
    for seq in (512, 1024, 4096, 8192, 32768):
        shape = dataclasses.replace(base, seq_len=seq,
                                    global_batch=max(256 // max(seq // 4096, 1), 32))
        us = timeit(lambda: materialize(cfg, shape, mesh), iters=3)
        adaptive = materialize(cfg, shape, mesh)
        a_bytes = estimate_bytes_per_device(cfg, shape, adaptive)
        static = static_peak_plan(cfg, shape, mesh)
        s_bytes = estimate_bytes_per_device(
            cfg, dataclasses.replace(shape, seq_len=32_768), static)
        saved = 100.0 * (1 - a_bytes / max(s_bytes, 1))
        row(f"fig8_mem_adapt/{arch}/seq{seq}", us,
            f"saved={saved:.1f}%;adaptive={a_bytes/GB:.2f}GiB;"
            f"static_peak={s_bytes/GB:.2f}GiB")


if __name__ == "__main__":
    main()
