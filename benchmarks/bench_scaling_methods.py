"""Paper Fig. 18: runtime scaling technologies under input growth.

BulkX compares adaptive materialization vs always-remote disaggregation
(swap) vs live migration.  TPU analogs for a component whose memory demand
grows with the input (the Join stage -> longer sequence):

  * adaptive      : re-materialize (remat/microbatch adjust) -- recompute
                    overhead only where needed
  * swap_all      : host-offload every activation (bandwidth-bound)
  * migrate       : move the whole job to a bigger allocation: pay full
                    state transfer at DCN bandwidth (best case, like the
                    paper's pure-data-movement migration bound)

Derived: modelled overhead seconds per step at each scale factor, from the
same hardware constants as §Roofline (HBM 819 GB/s, PCIe-class host link
~50 GB/s, DCN ~25 GB/s/pod).
"""

import dataclasses

try:
    from benchmarks.common import row, timeit
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import row, timeit
from repro.configs import SHAPES, get_config
from repro.core import profiles as prof
from repro.core.materializer import SINGLE_POD, materialize

HOST_BW = 50e9
DCN_BW = 25e9


def main() -> None:
    cfg = get_config("mistral-nemo-12b")
    base = SHAPES["train_4k"]
    mesh = SINGLE_POD
    for sf in (1, 4, 8):
        shape = dataclasses.replace(base, seq_len=base.seq_len * sf,
                                    global_batch=max(base.global_batch // sf, 32))
        us = timeit(lambda: materialize(cfg, shape, mesh), iters=3)
        plan = materialize(cfg, shape, mesh)
        # adaptive: recompute overhead = extra fwd pass when remat=full
        flops_dev = prof.step_model_flops(cfg, shape) / mesh.num_devices
        recompute = {"none": 0.0, "dots": 0.12, "full": 0.33}[plan.remat]
        t_adapt = flops_dev / mesh.peak_flops * recompute
        # swap-all: every saved activation crosses the host link
        act = prof.activation_bytes_train(cfg, shape, "none", 1,
                                          plan.attn_impl) / mesh.num_devices
        t_swap = 2 * act / HOST_BW
        # migration best case: move params+opt once per growth event
        state = (prof.param_bytes(cfg) + prof.optimizer_bytes(cfg)) \
            / mesh.num_devices
        t_migrate = state / DCN_BW
        row(f"fig18_scaling/sf{sf}", us,
            f"adaptive={t_adapt:.3f}s;swap={t_swap:.3f}s;"
            f"migrate={t_migrate:.3f}s;plan_remat={plan.remat};"
            f"mb={plan.microbatch}")


if __name__ == "__main__":
    main()
