"""Paper Fig. 10 / Fig. 14: ablation -- add one technique at a time.

Stages (TPU analog of the paper's static-DAG -> +resource graph ->
+adaptive -> +proactive):
  A  static-DAG:        peak-provisioned, remat none, naive attention,
                        no ZeRO/FSDP (each "function" holds everything)
  B  +resource graph:   component decomposition -> ZeRO over the DP group
  C  +adaptive:         locality ladder (remat/microbatch/FSDP/chunked)
  D  +proactive:        history-informed sizing (measured bytes feed back)

Derived: estimated GiB/device + roofline-bound step time from profiles.
"""

try:
    from benchmarks.common import row, timeit
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import row, timeit
from repro.configs import SHAPES, get_config
from repro.core.history import HistoryStore
from repro.core.materializer import (GB, SINGLE_POD,
                                     estimate_bytes_per_device, materialize)
from repro.core import profiles as prof


def main() -> None:
    cfg = get_config("qwen2-moe-a2.7b")
    shape = SHAPES["train_4k"]
    mesh = SINGLE_POD

    stages = {
        "A_static_dag": dict(zero=False, fsdp=False, remat="none",
                             microbatch=1, attn_impl="naive"),
        "B_resource_graph": dict(zero=True, fsdp=False, remat="none",
                                 microbatch=1, attn_impl="naive"),
        "C_adaptive": None,           # full ladder
        "D_proactive": "history",     # ladder + measured history
    }
    hist = HistoryStore()
    hist.observe(cfg.name, f"{shape.name}/{mesh.name}", "bytes_per_device",
                 9.5 * GB)

    for name, spec in stages.items():
        if spec == "history":
            us = timeit(lambda: materialize(cfg, shape, mesh, history=hist),
                        iters=5)
            plan = materialize(cfg, shape, mesh, history=hist)
        elif spec is None:
            us = timeit(lambda: materialize(cfg, shape, mesh), iters=5)
            plan = materialize(cfg, shape, mesh)
        else:
            plan = materialize(cfg, shape, mesh, overrides=spec)
            us = timeit(lambda: materialize(cfg, shape, mesh, overrides=spec),
                        iters=5)
        est = estimate_bytes_per_device(cfg, shape, plan)
        flops = prof.step_model_flops(cfg, shape) / mesh.num_devices
        t_bound = flops / mesh.peak_flops
        row(f"fig10_ablation/{name}", us,
            f"est={est/GB:.2f}GiB;compute_bound={t_bound*1e3:.1f}ms;"
            f"remat={plan.remat};mb={plan.microbatch};fsdp={plan.fsdp}")


if __name__ == "__main__":
    main()
