"""Paper Fig. 21: adaptive placement -- local / remote-scale / disagg.

The paper runs a fan-in ReduceBy with data components local, partially
remote, or fully disaggregated, showing I/O movement dominating as more
components go remote.  TPU analog on a decode cell's KV data component:

  * local        : KV heads co-located with their attention computes
                   (head-sharded; zero cross-chip KV traffic)
  * remote-scale : KV sequence-sharded; partial-softmax combines cross chips
  * disagg       : KV fully replicated-remote (batch-only sharding; every
                   access crosses the ICI)

Measured from fresh dry-run lowerings of whisper-base decode (small, fast
compile).  Derived: collective bytes/device + roofline collective term."""

import json
import os
import subprocess
import sys

try:
    from benchmarks.common import row
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import row

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def main() -> None:
    # run in a subprocess: needs the 512-device dry-run environment
    code = r"""
import json
from repro.configs.base import SHAPES
from repro.core.materializer import MESHES
from repro.launch.mesh import make_mesh_from_spec
from repro.launch.dryrun import lower_cell, collective_stats, memory_footprint
from repro.runtime import Application, Cluster, NullExecutor
import jax

shape = SHAPES["decode_32k"]
spec = MESHES["single_pod"]
mesh = make_mesh_from_spec(spec)
cluster = Cluster(pods=1, mesh=spec, executor=NullExecutor())
variants = {
  "local_headshard":  {"kv_shard_heads": True,  "kv_shard_seq": False},
  "remote_seqshard":  {"kv_shard_heads": False, "kv_shard_seq": True},
  "disagg_replicated":{"kv_shard_heads": False, "kv_shard_seq": False},
}
out = {}
for name, ov in variants.items():
    # each variant is one submitted invocation class; the handle carries
    # the materialized plan the dry-run lowers
    h = cluster.submit(Application.serve("whisper-base", shape=shape),
                       overrides=ov)
    l, _ = lower_cell(h.app.config, shape, h.plan, mesh)
    c = l.compile()
    cs = collective_stats(c.as_text())
    mem = memory_footprint(c)
    out[name] = {
        "coll_bytes": sum(d["bytes"] for d in cs.values()),
        "coll_counts": {k: d["count"] for k, d in cs.items() if d["count"]},
        "peak": mem["peak_tpu_adjusted"],
    }
    h.release()
    jax.clear_caches()
print("RESULT" + json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH=SRC, TF_CPP_MIN_LOG_LEVEL="3")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    payload = None
    for line in r.stdout.splitlines():
        if line.startswith("RESULT"):
            payload = json.loads(line[len("RESULT"):])
    if payload is None:
        row("fig21_placement/ERROR", 0.0, r.stderr[-200:].replace(",", ";"))
        return
    for name, d in payload.items():
        term = d["coll_bytes"] / 50e9
        row(f"fig21_placement/{name}", term * 1e6,
            f"coll_bytes={d['coll_bytes']};peak={d['peak']/2**30:.2f}GiB;"
            f"counts={d['coll_counts']}".replace(",", "|"))


if __name__ == "__main__":
    main()
