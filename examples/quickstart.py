"""Quickstart: the resource-centric model in one page.

Describe an annotated "bulky application" (here: a tiny LM training job),
submit it to a Cluster, and let the platform do its side of the contract:
decompose it into a resource graph, size it, place it on a pod,
materialize it adaptively for THIS invocation, and run a few steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import annotations as ann
from repro.core.history import HistoryStore
from repro.runtime import Application, Cluster, JaxExecutor


@ann.app_limit(max_chips=256)
@ann.compute(parallelism="token", name="my_training_app")
def app():
    """User 'source program': a monolithic training job."""
    return get_config("tinyllama-1.1b").scaled(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512)


def main():
    # 1. describe: the application -- not a function -- is the unit
    application = Application.from_callable(
        app, kind="train", shape=ShapeConfig("quickstart", "train", 32, 8))
    graph = application.resource_graph()
    print(f"resource graph: {len(graph.compute)} compute components, "
          f"{len(graph.data)} data components")
    for name, comp in list(graph.compute.items())[:4]:
        print(f"  @compute {name:24s} flops={comp.flops:.2e} "
              f"parallelism={comp.parallelism}")
    for name, d in list(graph.data.items())[:4]:
        print(f"  @data    {name:24s} bytes={d.bytes:.2e} "
              f"lifetime={d.lifetime}")

    # 2. submit: the platform sizes, places, and materializes it
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor())
    handle = cluster.submit(application)
    print(f"\nplaced on {handle.pod} "
          f"(demand {handle.job.demand_bytes / 2**20:.1f} MiB)")
    print("materialization plan for this invocation:")
    for note in handle.plan.notes:
        print("  ", note)
    p = handle.plan
    print(f"  -> tp={p.tp} fsdp={p.fsdp} zero={p.zero} "
          f"remat={p.remat} microbatch={p.microbatch}")

    # 3. execute a few steps (CPU-sized here; the same path runs on pods)
    for i in range(5):
        m = handle.step()
        print(f"step {i}: loss={m['loss']:.4f}")

    # 4. release: pod capacity returns exactly to its initial state
    handle.release()
    print(f"\nreleased; cluster capacity: {cluster.capacity()}")


if __name__ == "__main__":
    main()
