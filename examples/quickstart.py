"""Quickstart: the resource-centric model in one page.

Deploy an annotated "bulky application" (here: a tiny LM training job),
let Zenix decompose it into a resource graph, materialize it adaptively
for THIS invocation, and run a few steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.core import annotations as ann
from repro.core.graph import build_resource_graph
from repro.core.materializer import SINGLE_POD, materialize
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import ImplConfig, build_model
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step


@ann.app_limit(max_chips=256)
@ann.compute(parallelism="token", name="my_training_app")
def app():
    """User 'source program': a monolithic training job."""
    return get_config("tinyllama-1.1b").scaled(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512)


def main():
    cfg = app()
    shape = SHAPES["train_4k"]

    # 1. offline: decompose into the paper's resource graph
    graph = build_resource_graph(cfg, shape)
    print(f"resource graph: {len(graph.compute)} compute components, "
          f"{len(graph.data)} data components")
    for name, comp in list(graph.compute.items())[:4]:
        print(f"  @compute {name:24s} flops={comp.flops:.2e} "
              f"parallelism={comp.parallelism}")
    for name, d in list(graph.data.items())[:4]:
        print(f"  @data    {name:24s} bytes={d.bytes:.2e} "
              f"lifetime={d.lifetime}")

    # 2. per-invocation: adaptive materialization (the paper's core)
    plan = materialize(cfg, shape, SINGLE_POD)
    print("\nmaterialization plan for this invocation:")
    for note in plan.notes:
        print("  ", note)
    print(f"  -> tp={plan.tp} fsdp={plan.fsdp} zero={plan.zero} "
          f"remat={plan.remat} microbatch={plan.microbatch}")

    # 3. execute a few steps (CPU-sized here; the same code runs on pods)
    model = build_model(cfg, ImplConfig(remat="none"))
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init_opt_state(params)
    step = jax.jit(make_train_step(model, plan))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8))
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        print(f"step {i}: loss={float(m['loss']):.4f} "
              f"gnorm={float(m['grad_norm']):.3f}")


if __name__ == "__main__":
    main()
