"""End-to-end training driver on the runtime API: train an LM with the
full substrate -- history sizing, placement, adaptive materialization,
prefetching data pipeline, async checkpoints, straggler watchdog, crash
recovery -- all behind one Cluster.submit().

Presets:
  --preset ci    : ~3M params, 40 steps   (seconds; used by CI)
  --preset demo  : ~25M params, 200 steps (minutes on CPU)
  --preset full  : ~110M params, 300 steps (the assignment's ~100M target;
                   hours on CPU, minutes on a real pod)

Run:  PYTHONPATH=src python examples/train_lm.py --preset ci
"""

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.history import HistoryStore
from repro.runtime import Application, Cluster, JaxExecutor
from repro.training import optimizer as opt

PRESETS = {
    "ci": dict(layers=2, d_model=128, heads=4, d_ff=512, vocab=512,
               seq=64, batch=8, steps=40),
    "demo": dict(layers=4, d_model=256, heads=8, d_ff=1024, vocab=4096,
                 seq=128, batch=8, steps=200),
    "full": dict(layers=8, d_model=512, heads=8, d_ff=2048, vocab=32000,
                 seq=256, batch=8, steps=300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=list(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/zenix_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = get_config("tinyllama-1.1b").scaled(
        num_layers=p["layers"], d_model=p["d_model"], num_heads=p["heads"],
        num_kv_heads=max(p["heads"] // 2, 1),
        head_dim=p["d_model"] // p["heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab"])
    from repro.core.profiles import model_param_count
    print(f"model: {model_param_count(cfg)/1e6:.1f}M params "
          f"({p['layers']}L d={p['d_model']})")

    app = Application.train(
        cfg, shape=ShapeConfig("example", "train", p["seq"], p["batch"]),
        name=f"train-lm-{args.preset}", steps=p["steps"])
    ocfg = opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=10,
                               decay_steps=p["steps"])
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(ckpt_dir=args.ckpt_dir,
                                           ckpt_every=args.ckpt_every,
                                           resume=args.resume,
                                           opt_cfg=ocfg))
    handle = cluster.submit(app)
    last_note = handle.plan.notes[-1] if handle.plan.notes else handle.plan
    print("plan:", last_note)
    if handle.cursor:
        print(f"resumed from step {handle.cursor}")

    t_start = time.time()
    while handle.cursor < p["steps"]:
        m = handle.step()
        i = handle.cursor - 1
        if m["straggled"]:
            print(f"  [watchdog] step {i} straggled ({m['wall_s']:.2f}s)")
        if i % 10 == 0 or i == p["steps"] - 1:
            print(f"step {i:4d} loss={m['loss']:.4f} "
                  f"({m['wall_s']:.2f}s/step)")
    total = time.time() - t_start
    losses = [m["loss"] for m in handle.metrics]
    handle.release()
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"\ndone: loss {first:.3f} -> {last:.3f} "
          f"({100*(1-last/first):.1f}% reduction) in {total:.1f}s")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
