"""End-to-end training driver: train an LM with the full substrate --
adaptive materialization, data pipeline with prefetch, async checkpoints
at graph cuts, straggler watchdog, crash recovery.

Presets:
  --preset ci    : ~3M params, 40 steps   (seconds; used by CI)
  --preset demo  : ~25M params, 200 steps (minutes on CPU)
  --preset full  : ~110M params, 300 steps (the assignment's ~100M target;
                   hours on CPU, minutes on a real pod)

Run:  PYTHONPATH=src python examples/train_lm.py --preset ci
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,
                                           restore_checkpoint)
from repro.checkpoint.recovery import StragglerWatchdog
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.materializer import SINGLE_POD, materialize
from repro.data.pipeline import DataConfig, SyntheticLM, make_loader
from repro.models import ImplConfig, build_model
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step

PRESETS = {
    "ci": dict(layers=2, d_model=128, heads=4, d_ff=512, vocab=512,
               seq=64, batch=8, steps=40),
    "demo": dict(layers=4, d_model=256, heads=8, d_ff=1024, vocab=4096,
                 seq=128, batch=8, steps=200),
    "full": dict(layers=8, d_model=512, heads=8, d_ff=2048, vocab=32000,
                 seq=256, batch=8, steps=300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=list(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/zenix_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = get_config("tinyllama-1.1b").scaled(
        num_layers=p["layers"], d_model=p["d_model"], num_heads=p["heads"],
        num_kv_heads=max(p["heads"] // 2, 1),
        head_dim=p["d_model"] // p["heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab"])
    from repro.core.profiles import model_param_count
    n_params = model_param_count(cfg)
    print(f"model: {n_params/1e6:.1f}M params "
          f"({p['layers']}L d={p['d_model']})")

    shape = ShapeConfig("example", "train", p["seq"], p["batch"])
    plan = materialize(cfg, shape, SINGLE_POD)
    print("plan:", plan.describe()["notes"][-1] if plan.notes else plan)

    model = build_model(cfg, ImplConfig(remat="none"))
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    opt_state = opt.init_opt_state(params)
    ocfg = opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=10,
                               decay_steps=p["steps"])
    step = jax.jit(make_train_step(model, plan, ocfg))

    start = 0
    ck = AsyncCheckpointer(args.ckpt_dir, keep=2)
    if args.resume and latest_step(args.ckpt_dir) is not None:
        tree = {"params": params, "opt": opt_state}
        restored, extra, s = restore_checkpoint(args.ckpt_dir, None, tree)
        params, opt_state = restored["params"], restored["opt"]
        start = extra["cursor"]
        print(f"resumed from step {start}")

    dcfg = DataConfig(cfg.vocab_size, p["seq"], p["batch"])
    data = SyntheticLM(dcfg)
    wd = StragglerWatchdog()
    losses = []
    t_start = time.time()
    for i in range(start, p["steps"]):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        loss = float(m["loss"])
        losses.append(loss)
        wall = time.time() - t0
        if wd.observe(i, wall):
            print(f"  [watchdog] step {i} straggled ({wall:.2f}s)")
        if (i + 1) % args.ckpt_every == 0:
            ck.save(i + 1, {"params": params, "opt": opt_state},
                    extra={"cursor": i + 1})
        if i % 10 == 0 or i == p["steps"] - 1:
            print(f"step {i:4d} loss={loss:.4f} lr={float(m['lr']):.2e} "
                  f"({wall:.2f}s/step)")
    ck.wait()
    total = time.time() - t_start
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"\ndone: loss {first:.3f} -> {last:.3f} "
          f"({100*(1-last/first):.1f}% reduction) in {total:.1f}s")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
