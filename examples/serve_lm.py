"""Serving driver on the runtime API: continuous batching with
history-sized paged KV grants, behind the same Cluster.submit() path as
training.

Serves a small LM: prefill on admission, batched greedy decode, page-pool
growth via the §9.3 sizing policy, preemption under pressure.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12
"""

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.history import HistoryStore
from repro.runtime import Application, Cluster, JaxExecutor, ServeOptions
from repro.serving.kv_cache import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--backend", default="dense", choices=["dense", "paged"],
                    help="dense slot cache, or KV pages + paged-attention "
                         "kernel decode")
    args = ap.parse_args()

    cfg = get_config("tinyllama-1.1b").scaled(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512)
    app = Application.serve(
        cfg, shape=ShapeConfig("serve-demo", "decode", 64, args.max_batch),
        name="serve-lm",
        serve=ServeOptions(max_batch=args.max_batch, pool_pages=128,
                           cache_len=256, policy="history",
                           backend=args.backend))
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor())
    handle = cluster.submit(app)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        handle.submit_request(Request(f"req{i}", int(rng.integers(8, 64)),
                                      args.max_new))
    t0 = time.time()
    stats = handle.run(max_steps=10_000)
    wall = time.time() - t0
    pool = handle.engine.pool
    print(f"served {stats['completed']}/{args.requests} requests, "
          f"{stats['tokens_generated']} tokens in {wall:.1f}s "
          f"({stats['tokens_generated']/max(wall, 1e-9):.1f} tok/s)")
    print(f"prefills={stats['prefills']} "
          f"decode_steps={stats['decode_steps']} "
          f"preempted={stats['preempted']} "
          f"mean_ttft={stats['mean_ttft_s'] * 1e3:.1f}ms")
    print(f"pool: grants={pool.stats['grants']} "
          f"scaleups={pool.stats['scaleups']} "
          f"denials={pool.stats['denials']}")
    sz = pool.sizing()
    print(f"learned sizing: init={sz.init:.0f} pages, step={sz.step:.0f}")
    completed = stats["completed"]
    handle.release()
    assert completed == args.requests


if __name__ == "__main__":
    main()
