"""Serving driver: continuous batching with history-sized paged KV grants.

Serves a small LM: prefill on admission, batched greedy decode, page-pool
growth via the §9.3 sizing policy, preemption under pressure.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.history import HistoryStore
from repro.models import ImplConfig, build_model
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PAGE_SIZE, PagePool, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config("tinyllama-1.1b").scaled(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512)
    model = build_model(cfg, ImplConfig(remat="none"))
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)

    cache_len = 256
    slots = {}           # slot -> (Request, pos)
    cache = model.init_cache(args.max_batch, cache_len)
    decode = jax.jit(model.decode_step)
    prefill = jax.jit(lambda p, b, s: model.prefill(p, b, cache_len))

    state = {"cache": cache, "generated": {}}

    def prefill_fn(req):
        # prefill this request alone, write its row into the batch cache
        toks = jax.random.randint(jax.random.PRNGKey(hash(req.req_id) % 2**31),
                                  (1, req.prompt_len), 0, cfg.vocab_size)
        logits, rc = prefill(params, {"tokens": toks}, None)
        slot = min(set(range(args.max_batch))
                   - {s for s, _ in slots.values()})
        slots[req.req_id] = (slot, req.prompt_len)
        state["cache"] = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1),
            state["cache"], rc)
        state["generated"][req.req_id] = [int(jnp.argmax(logits[0, -1]))]

    def decode_fn(running):
        if not running:
            return
        toks = np.zeros((args.max_batch, 1), np.int32)
        pos = 0
        for req in running:
            slot, plen = slots[req.req_id]
            toks[slot, 0] = state["generated"][req.req_id][-1]
            pos = max(pos, plen + req.generated)
        logits, state["cache"] = decode(
            params, jnp.asarray(toks), state["cache"],
            jnp.asarray(pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        for req in running:
            slot, _ = slots[req.req_id]
            state["generated"][req.req_id].append(int(nxt[slot]))
            if req.generated + 1 >= req.max_new_tokens:
                slots.pop(req.req_id, None)

    hist = HistoryStore()
    pool = PagePool(128, history=hist, policy="history")
    eng = ServingEngine(pool, max_batch=args.max_batch,
                        step_fns=(prefill_fn, decode_fn), history=hist)

    rng_np = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(f"req{i}", int(rng_np.integers(8, 64)),
                           args.max_new))
    t0 = time.time()
    stats = eng.run_to_completion(max_steps=10_000)
    wall = time.time() - t0
    print(f"served {stats.completed}/{args.requests} requests, "
          f"{stats.tokens_generated} tokens in {wall:.1f}s "
          f"({stats.tokens_generated/max(wall,1e-9):.1f} tok/s)")
    print(f"prefills={stats.prefills} decode_steps={stats.decode_steps} "
          f"preempted={stats.preempted}")
    print(f"pool: grants={pool.stats['grants']} "
          f"scaleups={pool.stats['scaleups']} "
          f"denials={pool.stats['denials']}")
    sz = pool.sizing()
    print(f"learned sizing: init={sz.init:.0f} pages, step={sz.step:.0f}")
    assert stats.completed == args.requests


if __name__ == "__main__":
    main()
