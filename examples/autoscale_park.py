"""Autoscale + parking demo: the platform reclaims an idle serve app and
warm-restarts it on the next request.

A real (reduced) model serves a first burst through the paged backend,
goes idle, and the `repro.autoscale` control plane parks it -- KV pages
drained to host in the checkpointer's array format, pool pages and
scheduler bytes released.  The next ``submit_request`` transparently
unparks: the drained KV is scattered back into freshly granted pages and
decoding continues token-identically.

Run:  PYTHONPATH=src python examples/autoscale_park.py
"""

import numpy as np

from repro.core.history import HistoryStore
from repro.runtime import Application, Cluster, JaxExecutor, ServeOptions
from repro.serving.kv_cache import Request


def main():
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0))
    cluster.enable_autoscale(idle_park_s=2.0, confirm_ticks=1)
    handle = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="parkable",
        serve=ServeOptions(max_batch=4, pool_pages=32, cache_len=512,
                           backend="paged")))

    rng = np.random.default_rng(0)
    for i in range(3):                       # burst 1
        handle.submit_request(Request(f"r{i}", int(rng.integers(64, 256)),
                                      12))
    stats = handle.run(max_steps=5_000)
    print(f"burst 1: completed={stats['completed']} "
          f"tokens={stats['tokens_generated']}")

    for t in range(4):                       # idle: the parker fires
        cluster.tick(now=float(t))
    cap = cluster.capacity()[handle.pod]
    print(f"parked={handle.parked} demand_bytes={handle.job.demand_bytes} "
          f"pod_reserved={cap['reserved_bytes']}")
    assert handle.parked and handle.job.demand_bytes == 0

    # burst 2: submit_request unparks transparently (warm restart)
    for i in range(3, 6):
        handle.submit_request(Request(f"r{i}", int(rng.integers(64, 256)),
                                      12))
    print(f"after submit: parked={handle.parked}")
    stats = handle.run(max_steps=5_000)
    print(f"burst 2: completed={stats['completed']} "
          f"tokens={stats['tokens_generated']}")
    assert stats["completed"] == 6
    handle.release()
    print("released; capacity restored:",
          cluster.capacity()["pod0"]["free_bytes"])


if __name__ == "__main__":
    main()
