"""Elastic recovery demo: crash mid-training, restore from the latest
resource-graph cut, re-materialize on a SMALLER device pool, continue.

The resource-centric payoff (paper §2.3 vs migration): nothing about the
application changes across the resize -- ``handle.recover(new_mesh)``
re-materializes the SAME application on the new pool and restores the
latest persisted cut.

Run:  PYTHONPATH=src python examples/elastic_recovery.py
"""

import shutil
import tempfile

from repro.checkpoint.recovery import ElasticPolicy, FailureInjector
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.history import HistoryStore
from repro.core.materializer import MULTI_POD, SINGLE_POD
from repro.runtime import Application, Cluster, JaxExecutor


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="zenix_elastic_")
    cfg = get_config("tinyllama-1.1b").scaled(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512)
    app = Application.train(
        cfg, shape=ShapeConfig("demo", "train", 64, 8), name="elastic-demo")

    policy = ElasticPolicy([MULTI_POD, SINGLE_POD])
    cluster = Cluster(pods=1, mesh=policy.current_mesh(),
                      history=HistoryStore(),
                      executor=JaxExecutor(ckpt_dir=ckpt_dir, ckpt_every=5))
    handle = cluster.submit(app)
    print(f"initial mesh: {policy.current_mesh().name} "
          f"({policy.current_mesh().num_devices} chips), "
          f"batch_axes={handle.plan.batch_axes}")

    inj = FailureInjector(fail_at_steps=(12,))
    while handle.cursor < 20:
        try:
            inj.maybe_fail(handle.cursor)
            m = handle.step()
            if handle.cursor % 5 == 0:
                print(f"step {handle.cursor - 1}: loss={m['loss']:.3f}  "
                      "[cut recorded]")
        except RuntimeError as e:
            print(f"\n!! {e}")
            new_mesh = policy.shrink()
            print(f"elastic resize: -> {new_mesh.name} "
                  f"({new_mesh.num_devices} chips)")
            restart = handle.recover(new_mesh)
            print(f"re-materialized: batch_axes={handle.plan.batch_axes} "
                  f"tp={handle.plan.tp} (same application, new placement); "
                  f"replaying from step {restart}")

    handle.release()
    print(f"\ncompleted 20 steps despite the injected failure; "
          f"final mesh: {policy.current_mesh().name}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
