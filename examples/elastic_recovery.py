"""Elastic recovery demo: crash mid-training, restore from the latest
resource-graph cut, re-materialize on a SMALLER device pool, continue.

The resource-centric payoff (paper §2.3 vs migration): nothing about the
application changes across the resize -- only the physical materialization.

Run:  PYTHONPATH=src python examples/elastic_recovery.py
"""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import restore_checkpoint, save_checkpoint
from repro.checkpoint.recovery import (CutTracker, ElasticPolicy,
                                       FailureInjector, RecoveryPoint,
                                       elastic_replan)
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.materializer import MULTI_POD, SINGLE_POD, MeshSpec
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import ImplConfig, build_model
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="zenix_elastic_")
    cfg = get_config("tinyllama-1.1b").scaled(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512)
    shape = ShapeConfig("demo", "train", 64, 8)

    policy = ElasticPolicy([MULTI_POD, SINGLE_POD])
    plan = elastic_replan(cfg, shape, policy.current_mesh())
    print(f"initial mesh: {policy.current_mesh().name} "
          f"({policy.current_mesh().num_devices} chips), "
          f"batch_axes={plan.batch_axes}")

    model = build_model(cfg, ImplConfig(remat="none"))
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init_opt_state(params)
    step = jax.jit(make_train_step(model, plan))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8))
    cuts = CutTracker()
    inj = FailureInjector(fail_at_steps=(12,))

    i = 0
    while i < 20:
        try:
            inj.maybe_fail(i)
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, opt_state, m = step(params, opt_state, batch)
            if (i + 1) % 5 == 0:
                path = save_checkpoint(ckpt_dir, i + 1,
                                       {"p": params, "o": opt_state},
                                       extra={"cursor": i + 1})
                cuts.record(RecoveryPoint(i + 1, path, i + 1,
                                          policy.current_mesh().name))
                print(f"step {i}: loss={float(m['loss']):.3f}  [cut recorded]")
            i += 1
        except RuntimeError as e:
            start, lost = cuts.replay_span(i)
            print(f"\n!! {e} -- latest cut at step {start} "
                  f"({lost} steps to replay)")
            new_mesh = policy.shrink()
            print(f"elastic resize: -> {new_mesh.name} "
                  f"({new_mesh.num_devices} chips)")
            plan = elastic_replan(cfg, shape, new_mesh)
            print(f"re-materialized: batch_axes={plan.batch_axes} "
                  f"tp={plan.tp} (same resource graph, new placement)")
            restored, extra, _ = restore_checkpoint(
                ckpt_dir, None, {"p": params, "o": opt_state})
            params, opt_state = restored["p"], restored["o"]
            step = jax.jit(make_train_step(model, plan))
            i = extra["cursor"]

    print(f"\ncompleted 20 steps despite the injected failure; "
          f"final mesh: {policy.current_mesh().name}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
