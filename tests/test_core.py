"""Unit tests for the paper-core: materializer ladder, planner, resource
graph, history, scheduler, compile cache."""

import dataclasses

import pytest

from repro.configs import ALL_ARCHS, SHAPES, get_config, shape_applicable
from repro.core.graph import build_resource_graph
from repro.core.history import DecayedHistogram, HistoryStore
from repro.core.materializer import (MESHES, MULTI_POD, SINGLE_POD, GB,
                                     estimate_bytes_per_device, escalate,
                                     materialize)
from repro.core.compile_cache import CompileCache, plan_layout_key
from repro.core.scheduler import GlobalScheduler, Job, PodState
from repro.sharding import planner
from repro.models.transformer import model_specs
from repro.models import layers as L


# ---------------------------------------------------------------------------
# materializer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", ["single_pod", "multi_pod"])
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_materialize_all_cells(arch, mesh):
    cfg = get_config(arch)
    for sname, shape in SHAPES.items():
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        plan = materialize(cfg, shape, MESHES[mesh])
        # batch axes must divide the global batch
        deg = plan.dp_degree
        assert shape.global_batch % max(deg, 1) == 0, (arch, sname)
        # microbatch respects DP divisibility
        if shape.kind == "train":
            assert (shape.global_batch // max(deg, 1)) % plan.microbatch == 0
        # MoE archs get EP whenever TP is on
        if cfg.moe is not None and plan.tp:
            assert plan.ep
        # decode shapes pick exactly one KV sharding strategy
        if shape.is_decode:
            assert plan.kv_shard_heads != plan.kv_shard_seq
            if cfg.num_kv_heads % 16 == 0:
                assert plan.kv_shard_heads
        assert plan.notes, "plan must carry an audit trail"


def test_ladder_escalates_under_pressure():
    cfg = get_config("dbrx-132b")
    shape = SHAPES["train_4k"]
    plan = materialize(cfg, shape, SINGLE_POD)
    # a 132B train job cannot be all-local: ladder must have escalated
    assert plan.tp and plan.fsdp and plan.zero
    assert plan.remat in ("dots", "full")


def test_all_local_for_small_model():
    cfg = get_config("tinyllama-1.1b")
    plan = materialize(cfg, SHAPES["train_4k"], SINGLE_POD)
    assert not plan.tp, "1.1B train should materialize all-local (pure DP)"
    assert plan.dp_degree == 256


def test_estimate_monotone_in_ladder():
    cfg = get_config("command-r-35b")
    shape = SHAPES["train_4k"]
    base = materialize(cfg, shape, SINGLE_POD,
                       overrides={"remat": "none", "microbatch": 1,
                                  "fsdp": False, "zero": False})
    est0 = estimate_bytes_per_device(cfg, shape, base)
    for kw in ({"zero": True}, {"remat": "full"}, {"fsdp": True},
               {"microbatch": 4}):
        nxt = dataclasses.replace(base, **kw)
        assert estimate_bytes_per_device(cfg, shape, nxt) <= est0, kw


def test_escalate_chain_terminates():
    cfg = get_config("mistral-nemo-12b")
    shape = SHAPES["train_4k"]
    plan = materialize(cfg, shape, SINGLE_POD)
    seen = set()
    for _ in range(24):
        key = (plan.remat, plan.microbatch, plan.fsdp, plan.zero,
               plan.attn_impl, plan.tp, plan.offload_optimizer,
               plan.fsdp_contracting, plan.loss_chunk)
        assert key not in seen, "escalation revisited a state"
        seen.add(key)
        nxt = escalate(plan, cfg, shape, measured_bytes=1 << 60)
        if nxt is None:
            break
        plan = nxt
    else:
        pytest.fail("escalation did not terminate")


def test_long_context_seq_axes():
    cfg = get_config("gemma3-12b")
    plan = materialize(cfg, SHAPES["long_500k"], MULTI_POD)
    assert plan.batch_axes == ()          # batch 1 cannot shard
    assert plan.seq_axes, "long-context decode must shard the sequence"


# ---------------------------------------------------------------------------
# sharding planner
# ---------------------------------------------------------------------------

def _axes_size(mesh_spec, axes):
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh_spec.axis_size(a)
    return n


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    plan = materialize(cfg, SHAPES["train_4k"], SINGLE_POD)
    specs = model_specs(cfg)
    ptree = planner.param_specs_tree(plan, cfg, specs)
    flat_specs = jax.tree.leaves(specs, is_leaf=L.is_spec)
    flat_parts = jax.tree.leaves(
        ptree, is_leaf=lambda x: isinstance(x, planner.P))
    assert len(flat_specs) == len(flat_parts)
    for s, p in zip(flat_specs, flat_parts):
        for dim, entry in enumerate(p):
            if entry is None:
                continue
            assert s.shape[dim] % _axes_size(plan.mesh, entry) == 0, (
                arch, s.shape, p)


import jax  # noqa: E402  (after use above in tree ops)


def test_kv_heads_not_sharded_when_indivisible():
    cfg = get_config("mistral-nemo-12b")   # kv=8 vs model=16
    plan = materialize(cfg, SHAPES["train_4k"], SINGLE_POD)
    specs = model_specs(cfg)
    ptree = planner.param_specs_tree(plan, cfg, specs)
    wk = ptree["blocks"]["p0_attn_global"]["attn"]["wk"]
    assert "model" not in jax.tree.leaves(wk, is_leaf=lambda x: True)[0][2:3]


# ---------------------------------------------------------------------------
# resource graph
# ---------------------------------------------------------------------------

def test_graph_structure_dense():
    cfg = get_config("mistral-nemo-12b")
    g = build_resource_graph(cfg, SHAPES["train_4k"])
    order = g.topo_order()
    assert order[0] == "embed" and order[-1] == "optimizer"
    assert g.total_flops() > 0
    assert "optimizer" in g.cut_boundaries() or "head" in g.cut_boundaries()


def test_graph_shared_data_zamba():
    cfg = get_config("zamba2-2.7b")
    g = build_resource_graph(cfg, SHAPES["train_4k"])
    assert "w_shared_attn" in g.data


def test_graph_moe_dispatch_component():
    cfg = get_config("dbrx-132b")
    g = build_resource_graph(cfg, SHAPES["train_4k"])
    disp = [d for d in g.data.values() if d.input_dependent
            and d.lifetime == "transient"]
    assert disp, "MoE dispatch buffer must be an input-dependent component"


def test_graph_decode_kv_component():
    cfg = get_config("mistral-nemo-12b")
    g = build_resource_graph(cfg, SHAPES["decode_32k"])
    assert g.data["kv_cache"].bytes > 0
    assert len(g.accessors("kv_cache")) >= 1


# ---------------------------------------------------------------------------
# history
# ---------------------------------------------------------------------------

def test_decayed_histogram_quantiles():
    h = DecayedHistogram()
    for v in [10, 20, 30, 40, 1000]:
        h.observe(v)
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0)
    assert h.peak() >= 500


def test_history_decay_forgets():
    h = DecayedHistogram(decay=0.5)
    h.observe(1000.0)
    for _ in range(20):
        h.observe(10.0)
    assert h.quantile(0.9) < 100


def test_history_store_persistence(tmp_path):
    st = HistoryStore(str(tmp_path))
    st.observe("app", "comp", "bytes", 123456)
    st.save()
    st2 = HistoryStore(str(tmp_path))
    assert st2.peak("app", "comp", "bytes") > 0


# ---------------------------------------------------------------------------
# two-level scheduler
# ---------------------------------------------------------------------------

def test_scheduler_best_fit_smallest():
    pods = [PodState("a", 256, 16 * GB), PodState("b", 128, 16 * GB)]
    sched = GlobalScheduler(pods)
    job = Job("j1", "app", "train", 100 * GB, 64)
    pod = sched.submit(job)
    assert pod == "b", "must pick the smallest sufficient pod"


def test_scheduler_queues_and_drains():
    pods = [PodState("a", 4, 16 * GB)]
    sched = GlobalScheduler(pods)
    j1 = Job("j1", "app", "train", 60 * GB, 4)
    j2 = Job("j2", "app", "train", 60 * GB, 4)
    assert sched.submit(j1) == "a"
    assert sched.submit(j2) is None        # queued
    assert len(sched.pending) == 1
    sched.finish(j1)
    assert j2.pod == "a" and not sched.pending


# (scheduler throughput is asserted in tests/test_runtime.py via the
# runtime's replay_trace -- the single simulation path after PR 1)


# ---------------------------------------------------------------------------
# compile cache
# ---------------------------------------------------------------------------

def test_compile_cache_single_flight_and_hits():
    cc = CompileCache()
    calls = []

    def build():
        calls.append(1)
        return "exe"

    assert cc.get_or_compile("k1", build) == "exe"
    assert cc.get_or_compile("k1", build) == "exe"
    assert len(calls) == 1
    assert cc.stats["hits"] == 1


def test_plan_layout_key_stable():
    cfg = get_config("tinyllama-1.1b")
    p1 = materialize(cfg, SHAPES["train_4k"], SINGLE_POD)
    p2 = materialize(cfg, SHAPES["train_4k"], SINGLE_POD)
    assert plan_layout_key("a", "s", "m", p1) == plan_layout_key("a", "s", "m", p2)
    p3 = dataclasses.replace(p2, microbatch=p2.microbatch * 2)
    p3.notes = []
    assert plan_layout_key("a", "s", "m", p2) != plan_layout_key("a", "s", "m", p3)
