"""The repro.obs observability plane: tracer ring semantics, histogram
math and windowed deltas, Prometheus exposition, both trace export
formats round-tripping through the loader, the CLI summary, and --
critically -- exact request-lifecycle reconstruction: the events an
instrumented run captures must agree with the engine's own counters,
and the whole plane must be a no-op when disabled."""

import json
import subprocess
import sys

import pytest

from repro import obs
from repro.autoscale import MetricsWindow, stats_delta
from repro.core.history import HistoryStore
from repro.obs.metrics import (LATENCY_BOUNDS, OCCUPANCY_BOUNDS, Histogram,
                               hist_delta, hist_merge)
from repro.obs.summary import pctl, request_lifecycles, summarize
from repro.runtime import (Application, Cluster, NullExecutor,
                           ServeOptions)
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PAGE_SIZE, PagePool, Request


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with the plane disabled (the module
    globals are process-wide; a leak would instrument unrelated tests)."""
    obs.disable()
    obs.disable_metrics()
    yield
    obs.disable()
    obs.disable_metrics()


def _drive(n=6, prompt=48, gen=8, max_batch=4):
    """A small null-backend engine run; returns (engine, pool)."""
    pool = PagePool(64)
    eng = ServingEngine(pool, max_batch=max_batch)
    for i in range(n):
        eng.submit(Request(f"r{i}", prompt, gen))
    steps = 0
    while eng.step() and steps < 50_000:
        steps += 1
    return eng, pool


# ---------------------------------------------------------------------------
# tracer ring
# ---------------------------------------------------------------------------

def test_ring_bounds_and_drop_accounting():
    t = obs.enable(capacity=8)
    for i in range(20):
        t.instant("request", "submit", f"r{i}")
    assert len(t) == 8
    assert t.dropped == 12
    # oldest dropped, newest kept
    assert t.snapshot()[0][5] == "r12" and t.snapshot()[-1][5] == "r19"
    t.clear()
    assert len(t) == 0 and t.dropped == 0


def test_tracer_accessors_and_span():
    t = obs.enable()
    t.instant("pool", "grant", "a", {"pages": 2})
    t.span("request", "prefill", 1.0, 1.5, "r0", {"prompt_len": 32})
    t.instant("request", "finish", "r0")
    assert [e[4] for e in t.by_scope("r0")] == ["prefill", "finish"]
    (ev,) = t.by_name("prefill", "request")
    assert ev[2] == "X" and ev[1] == pytest.approx(0.5)
    assert t.by_name("grant")[0][6] == {"pages": 2}
    assert t.by_name("grant", "request") == []  # cat filter applies


def test_disabled_plane_emits_nothing():
    assert obs.current() is None and obs.current_metrics() is None
    eng, _ = _drive()              # instrumented code runs with plane off
    assert eng.stats.completed == 6
    assert obs.current() is None, "a run must not implicitly enable obs"


# ---------------------------------------------------------------------------
# histograms + registry
# ---------------------------------------------------------------------------

def test_histogram_observe_percentile_mean():
    h = Histogram(bounds=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.count == 5 and h.sum == pytest.approx(106.5)
    assert h.counts == [1, 2, 1, 1]    # last bucket = overflow
    assert h.percentile(50) == 2.0     # upper-edge approximation
    assert h.percentile(99) == 4.0     # +inf clamps to last finite edge
    assert h.mean == pytest.approx(106.5 / 5)
    assert Histogram().percentile(50) == 0.0   # empty


def test_histogram_dict_roundtrip_merge_and_bounds_guard():
    a, b = Histogram(bounds=(1.0, 2.0)), Histogram(bounds=(1.0, 2.0))
    a.observe(0.5), b.observe(5.0)
    m = Histogram.from_dict(hist_merge([a.to_dict(), b.to_dict()]))
    assert m.count == 2 and m.counts == [1, 0, 1]
    with pytest.raises(ValueError, match="different"):
        a.merge(Histogram(bounds=(1.0, 3.0)))


def test_hist_delta_window_and_reset_clamp():
    cur = {"bounds": [1.0], "counts": [3, 2], "sum": 9.0, "count": 5}
    since = {"bounds": [1.0], "counts": [1, 1], "sum": 3.0, "count": 2}
    d = hist_delta(cur, since)
    assert d == {"bounds": [1.0], "counts": [2, 1], "sum": 6.0, "count": 3}
    # None baseline and bounds mismatch both pass cur through (a copy)
    assert hist_delta(cur, None) == cur and hist_delta(cur, None) is not cur
    assert hist_delta(cur, {"bounds": [2.0], "counts": [9, 9],
                            "sum": 0.0, "count": 18}) == cur
    # counter reset (since > cur in any bucket): clamp to cur, never
    # negative counts
    reset = hist_delta(cur, {"bounds": [1.0], "counts": [5, 0],
                             "sum": 1.0, "count": 5})
    assert reset["counts"] == [3, 2] and reset["count"] == 5


def test_registry_render_prometheus_format():
    m = obs.enable_metrics()
    m.inc("repro_requests_total", 3, app="a")
    m.set_gauge("repro_queue_len", 7, app="a")
    h = m.histogram("repro_ttft_seconds", bounds=(0.1, 1.0), app="a")
    h.observe(0.05), h.observe(0.5), h.observe(50.0)
    text = m.render()
    assert '# TYPE repro_ttft_seconds histogram' in text
    assert 'repro_requests_total{app="a"} 3' in text
    assert 'repro_queue_len{app="a"} 7' in text
    # cumulative le buckets, then +Inf == _count
    assert 'repro_ttft_seconds_bucket{app="a",le="0.1"} 1' in text
    assert 'repro_ttft_seconds_bucket{app="a",le="1"} 2' in text
    assert 'repro_ttft_seconds_bucket{app="a",le="+Inf"} 3' in text
    assert 'repro_ttft_seconds_count{app="a"} 3' in text
    assert m.app_histograms("a")["repro_ttft_seconds"]["count"] == 3
    assert m.app_histograms("nope") == {}
    # get-or-create returns the SAME object (hot paths hold it)
    assert m.histogram("repro_ttft_seconds", app="a") is h


# ---------------------------------------------------------------------------
# lifecycle reconstruction: trace events vs the engine's own counters
# ---------------------------------------------------------------------------

def test_trace_matches_engine_counters():
    t = obs.enable()
    m = obs.enable_metrics()
    eng, _ = _drive(n=6)
    s = eng.stats
    assert len(t.by_name("submit", "request")) == 6
    assert len(t.by_name("admit", "request")) == s.admitted
    assert len(t.by_name("finish", "request")) == s.completed == 6
    assert len(t.by_name("first_token", "request")) == s.ttft_count
    assert len(t.by_name("decode_step", "engine")) == s.decode_steps
    assert len(t.by_name("prefill", "request")) == s.prefills
    # finish args carry per-request token counts summing to the total
    toks = sum(e[6]["tokens"] for e in t.by_name("finish", "request"))
    assert toks == s.tokens_generated
    # the metrics plane saw the same population
    hists = m.app_histograms("serve")
    assert hists["repro_ttft_seconds"]["count"] == s.ttft_count
    assert hists["repro_queue_wait_seconds"]["count"] == s.admitted
    assert hists["repro_batch_occupancy"]["count"] == s.decode_steps
    # a null engine has no decode fn: nothing to time, so no decode
    # latency histogram may appear (absence IS the correct reading)
    assert "repro_decode_step_seconds" not in hists
    # every admit records a non-negative queue wait
    assert all(e[6]["queue_wait_s"] >= 0.0
               for e in t.by_name("admit", "request"))


def test_pool_events_and_preempt():
    # pool arbitration events emit from the pod-shared PoolView (the
    # tenancy layer) -- a tiny quota forces denials and preemptions
    t = obs.enable()
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=8)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="obs-pool",
        serve=ServeOptions(max_batch=4)))
    for i in range(4):
        h.submit_request(Request(f"r{i}", PAGE_SIZE - 4, 3 * PAGE_SIZE))
    h.run(max_steps=50_000)
    eng = h.engine
    grants = t.by_name("grant", "pool")
    assert grants and all(e[6]["pages"] >= 1 for e in grants)
    assert all(e[5] == "obs-pool" for e in grants), "scope = the app"
    if eng.pool.stats["denials"]:
        denials = t.by_name("denial", "pool")
        assert denials and denials[0][6]["cause"] in ("quota", "physical")
    assert len(t.by_name("preempt", "request")) == eng.stats.preempted
    h.release()


def test_park_unpark_and_autoscale_events():
    t = obs.enable()
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=32)
    cluster.enable_autoscale(idle_park_s=2.0, confirm_ticks=1)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="obs-park",
        serve=ServeOptions(max_batch=4)))
    # direct park with a request mid-flight: the drain must be visible
    h.submit_request(Request("r0", PAGE_SIZE - 4, 300))
    for _ in range(3):
        h.step()
    h.park()
    (park,) = t.by_name("park", "autoscale")
    assert park[5] == "obs-park" and park[6]["drained_requests"] == 1
    assert [e[5] for e in t.by_name("park", "request")] == ["r0"]
    h.unpark()
    (unpark,) = t.by_name("unpark", "autoscale")
    assert unpark[5] == "obs-park" and unpark[6]["restored_requests"] == 1
    (rup,) = t.by_name("unpark", "request")
    assert rup[5] == "r0" and rup[6]["restored"] is True
    # scheduler-plane receipts for the same episode
    assert t.by_name("job_park", "scheduler")
    assert t.by_name("job_unpark", "scheduler")
    h.run(max_steps=50_000)
    # controller-driven park after sustained idleness: the decision
    # event must explain itself (rule + the windowed rates it saw)
    tick = 0.0
    while not h.parked and tick < 20.0:
        cluster.tick(now=tick)
        tick += 1.0
    assert h.parked
    (dec,) = [e for e in t.by_name("decision", "autoscale")
              if e[6]["action"] == "park"]
    assert dec[5] == "obs-park" and "reason" in dec[6]
    assert any(k.startswith("rate_") for k in dec[6]), \
        "a decision must carry the windowed rates it saw"
    h.submit_request(Request("r1", 32, 4))   # transparent unpark
    assert not h.parked
    h.run(max_steps=50_000)
    h.release()
    assert t.by_name("job_finish", "scheduler")


# ---------------------------------------------------------------------------
# exporters + CLI
# ---------------------------------------------------------------------------

def _traced_run(tmp_path, fmt):
    t = obs.enable()
    eng, _ = _drive(n=4)
    path = str(tmp_path / f"trace.{fmt}")
    n = (obs.write_jsonl(t, path) if fmt == "jsonl"
         else obs.write_chrome_trace(t, path, extra_meta={"k": "v"}))
    return t, eng, path, n


@pytest.mark.parametrize("fmt", ["json", "jsonl"])
def test_export_roundtrip(tmp_path, fmt):
    t, eng, path, n = _traced_run(tmp_path, fmt)
    assert n == len(t)
    events = obs.load_events(path)
    assert len(events) == len(t), "loader must drop only metadata rows"
    reqs = request_lifecycles(events)
    assert len(reqs) == 4
    for r in reqs.values():
        assert r["submit"] is not None and r["finish"] is not None
        assert r["finish"] >= r["submit"] >= 0.0   # ts relative to t0
        assert r["ttft"] is not None and r["tokens"] == 8
    # durations survive in seconds through either format (a null engine
    # emits prefill as an instant -- dur 0 -- rather than a span)
    prefills = [e for e in events if e["name"] == "prefill"]
    assert len(prefills) == eng.stats.prefills
    assert all(0.0 <= e["dur"] < 60.0 for e in prefills)
    assert all(e["args"]["prompt_len"] == 48 for e in prefills)


def test_chrome_trace_shape(tmp_path):
    t, _, path, _ = _traced_run(tmp_path, "json")
    with open(path) as f:
        doc = json.load(f)
    assert doc["otherData"]["dropped_events"] == 0
    assert doc["otherData"]["k"] == "v"
    evs = doc["traceEvents"]
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"request", "engine", "pool"} <= procs
    lanes = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"r0", "r1", "r2", "r3"} <= lanes
    # every non-meta event has a resolvable pid/tid and us timestamps
    assert all(e["ts"] >= 0.0 for e in evs if e["ph"] != "M")


def test_cli_summary(tmp_path):
    _, eng, path, _ = _traced_run(tmp_path, "json")
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs", path],
        capture_output=True, text=True, check=True).stdout
    assert "== trace summary ==" in out
    assert f"decode steps: {eng.stats.decode_steps}" in out
    assert "p50=" in out and "p95=" in out and "p99=" in out
    assert "ttft" in out and "queue_wait" in out and "decode_step" in out
    assert "== slowest request" in out
    # the lifecycle table has one row per request
    assert all(f"r{i} " in out for i in range(4))


def test_summarize_handles_sparse_traces():
    assert "requests: 0" in summarize([])
    only_pool = [{"ts": 0.0, "dur": 0.0, "ph": "i", "cat": "pool",
                  "name": "grant", "scope": "a", "args": {"pages": 1}}]
    assert "pool=1" in summarize(only_pool)
    assert pctl([], 99) == 0.0 and pctl([3.0], 50) == 3.0


# ---------------------------------------------------------------------------
# windowed-stats edge cases (stats_delta / MetricsWindow satellites)
# ---------------------------------------------------------------------------

def _raw(admitted=4, **over):
    d = {"admitted": admitted, "completed": admitted, "rejected": 0,
         "preempted": 0, "decode_steps": admitted, "prefills": admitted,
         "tokens_generated": 2 * admitted, "ttft_s_sum": 0.0,
         "ttft_count": 0, "decode_s_sum": 0.0}
    d.update(over)
    return d


def test_stats_delta_missing_subdicts():
    # no pool/shared_pool/hist anywhere: plain counters still window
    d = stats_delta(_raw(6), _raw(2))
    assert d["admitted"] == 4 and "pool" not in d and "hist" not in d
    # since lacks (or corrupts) the sub-dicts cur carries
    cur = _raw(6, pool={"grants": 5, "denials": 2, "num_pages": 64},
               shared_pool={"cross_app_preemptions": 3,
                            "denials_by_app": {"a": 2}},
               hist={"h": {"bounds": [1.0], "counts": [2, 0],
                           "sum": 1.0, "count": 2}})
    for bad_since in (_raw(2),
                      _raw(2, pool=None, shared_pool=7, hist="nope")):
        d = stats_delta(cur, bad_since)
        assert d["pool"]["grants"] == 5 and d["pool"]["num_pages"] == 64
        assert d["shared_pool"]["cross_app_preemptions"] == 3
        assert d["shared_pool"]["denials_by_app"] == {"a": 2}
        assert d["hist"]["h"]["count"] == 2


def test_stats_delta_counter_reset_clamps():
    # a fresh engine under an old name: since > cur everywhere
    d = stats_delta(_raw(1, pool={"grants": 1, "denials": 0},
                         shared_pool={"cross_app_preemptions": 0,
                                      "denials_by_app": {"a": 0}}),
                    _raw(9, pool={"grants": 9, "denials": 4},
                         shared_pool={"cross_app_preemptions": 5,
                                      "denials_by_app": {"a": 7}}))
    assert d["admitted"] == 0 and d["pool"]["grants"] == 0
    assert d["shared_pool"]["cross_app_preemptions"] == 0
    assert d["shared_pool"]["denials_by_app"] == {"a": 0}


def test_stats_delta_zero_count_window_means():
    d = stats_delta(_raw(4), _raw(4))
    assert d["mean_ttft_s"] == 0.0 and d["mean_decode_step_s"] == 0.0


def test_metrics_window_zero_count_holds_ewma():
    w = MetricsWindow(alpha=1.0)
    w.observe(_raw(0), now=0.0)
    w.observe(_raw(4, ttft_s_sum=2.0, ttft_count=4), now=1.0)
    assert w.rates["ttft_s"] == pytest.approx(0.5)
    # an idle window (no ttft samples) must HOLD the smoothed value,
    # not decay it toward a fake 0.0
    w.observe(_raw(4, ttft_s_sum=2.0, ttft_count=4), now=2.0)
    assert w.rates["ttft_s"] == pytest.approx(0.5)
    assert w.idle_s == pytest.approx(1.0)


def test_serving_stats_hist_windows_through_since():
    obs.enable_metrics()
    cluster = Cluster(pods=1, executor=NullExecutor(), pool_pages=64)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="histwin",
        serve=ServeOptions(max_batch=4)))
    for i in range(3):
        h.submit_request(Request(f"r{i}", 16, 4))
    while h.step()["alive"]:
        pass
    mark = h.serving_stats()
    assert mark["hist"]["repro_ttft_seconds"]["count"] == 3
    assert mark["hist"]["repro_batch_occupancy"]["bounds"] == \
        list(OCCUPANCY_BOUNDS)
    for i in range(3, 5):
        h.submit_request(Request(f"r{i}", 16, 4))
    while h.step()["alive"]:
        pass
    win = h.serving_stats(since=mark)
    assert win["hist"]["repro_ttft_seconds"]["count"] == 2, \
        "histograms must window like every other counter"
    assert win["hist"]["repro_ttft_seconds"]["bounds"] == \
        list(LATENCY_BOUNDS)
    h.release()
