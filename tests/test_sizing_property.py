"""Property-based tests (hypothesis) for the §9.3 sizing program and other
system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.sizing import peak_sizing, simulate_policy, solve_init_step
from repro.core.history import DecayedHistogram

usage_lists = st.lists(st.floats(min_value=1.0, max_value=1e6,
                                 allow_nan=False, allow_infinity=False),
                       min_size=1, max_size=40)


@settings(max_examples=40, deadline=None)
@given(usage_lists, st.floats(min_value=0.01, max_value=2.0))
def test_sizing_covers_every_history_point(vals, cost_factor):
    """Feasibility constraint: k_h * step + init >= h for all h."""
    hist = [(v, 1.0) for v in vals]
    sol = solve_init_step(hist, cost_factor=cost_factor)
    for v in vals:
        k = np.ceil(max(v - sol.init, 0.0) / max(sol.step, 1e-9))
        assert k * sol.step + sol.init >= v - 1e-6


@settings(max_examples=40, deadline=None)
@given(usage_lists)
def test_sizing_waste_bounded_when_feasible(vals):
    hist = [(v, 1.0) for v in vals]
    sol = solve_init_step(hist, waste_threshold=0.25)
    if sol.feasible and len(set(vals)) > 1:
        sim = simulate_policy(vals, sol)
        # allocated never below used
        assert sim["mean_alloc"] >= sim["mean_used"] - 1e-6


@settings(max_examples=25, deadline=None)
@given(usage_lists)
def test_history_sizing_respects_waste_constraint(vals):
    """When feasible, the chosen point satisfies the waste constraint, and
    it never costs more than the cheapest *grid-representable* peak
    candidate (init grid is quantum-ceiled, so the raw peak itself may be
    unreachable/infeasible)."""
    hist = [(v, 1.0) for v in vals]
    sol = solve_init_step(hist, cost_factor=0.3, waste_threshold=0.25)
    if sol.feasible:
        assert sol.waste_ratio < 0.25 + 1e-9
        peak_q = float(np.ceil(max(max(vals), 1.0)))
        vq = [max(v, 1.0) for v in vals]
        peak_q_waste = float(np.mean([peak_q - v for v in vq])
                             / max(np.mean(vq), 1e-9))
        if peak_q_waste < 0.25:
            assert sol.expected_cost <= peak_q + 1e-6


@settings(max_examples=30, deadline=None)
@given(usage_lists)
def test_peak_policy_never_scales_up(vals):
    sol = peak_sizing([(v, 1.0) for v in vals])
    sim = simulate_policy(vals, sol)
    assert sim["mean_scaleups"] == 0.0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(min_value=1, max_value=1e5, allow_nan=False),
                min_size=2, max_size=60))
def test_histogram_quantile_monotone(vals):
    h = DecayedHistogram()
    for v in vals:
        h.observe(v)
    qs = [h.quantile(q) for q in (0.1, 0.5, 0.9, 1.0)]
    assert all(a <= b + 1e-9 for a, b in zip(qs, qs[1:]))
    # peak bucket must contain the max (log-bucket upper bound)
    assert h.peak() >= max(vals) / 1.5


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=512), min_size=1,
                max_size=30),
       st.integers(min_value=1, max_value=8))
def test_page_pool_conservation(lengths, step_pages):
    """Pages are conserved: free + granted == total, always."""
    from repro.serving.kv_cache import PagePool, Request
    pool = PagePool(num_pages=256, policy="fixed", fixed_init_pages=2,
                    fixed_step_pages=step_pages)
    reqs = [Request(f"r{i}", l, 4) for i, l in enumerate(lengths)]
    granted = []
    for r in reqs:
        if pool.try_admit(r):
            granted.append(r)
        used = sum(len(x.pages) for x in granted)
        assert used + len(pool.free) == 256
    for r in granted:
        r.generated = r.max_new_tokens
        pool.release(r)
    assert len(pool.free) == 256


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=64),
       st.integers(min_value=1, max_value=16))
def test_capacity_dispatch_conservation(t, k):
    """MoE router: combine weights are normalized; dropped tokens only
    reduce (never corrupt) the output."""
    import jax.numpy as jnp
    import jax
    from repro.configs import get_config
    from conftest import reduced_config
    from repro.models.moe import route
    cfg = reduced_config(get_config("qwen2-moe-a2.7b"))
    k = min(k, cfg.moe.num_experts)
    import dataclasses as dc
    cfg = cfg.scaled(moe=dc.replace(cfg.moe, top_k=k))
    x = jax.random.normal(jax.random.PRNGKey(t), (t, cfg.d_model),
                          jnp.bfloat16)
    import numpy as np
    router = jax.random.normal(jax.random.PRNGKey(1),
                               (cfg.d_model, 8), jnp.bfloat16)
    w, ids, aux = route(router, x, cfg)
    w = np.asarray(w, np.float32)
    ids = np.asarray(ids)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=2e-2)
    assert (ids < cfg.moe.num_experts).all(), "padded experts must not route"
    assert np.isfinite(float(aux))
