"""Serving stack: page pool sizing policies, continuous batching engine,
preemption, and engine-with-real-model integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_config
from repro.configs import get_config
from repro.core.history import HistoryStore
from repro.models import ImplConfig, build_model
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import (PAGE_SIZE, PagePool, Request, page_table,
                                    pool_pages_for_budget)


def test_pool_admit_grow_release():
    pool = PagePool(32, policy="fixed", fixed_init_pages=2, fixed_step_pages=1)
    r = Request("r", prompt_len=PAGE_SIZE * 3, max_new_tokens=PAGE_SIZE)
    assert pool.try_admit(r)
    assert len(r.pages) == 3
    r.generated = PAGE_SIZE  # outgrow
    assert pool.grow(r)
    assert len(r.pages) == 4
    pool.release(r)
    assert len(pool.free) == 32


def test_pool_denial_when_exhausted():
    pool = PagePool(4, policy="fixed", fixed_init_pages=4)
    r1 = Request("a", PAGE_SIZE, 1)
    r2 = Request("b", PAGE_SIZE, 1)
    assert pool.try_admit(r1)
    assert not pool.try_admit(r2)
    assert pool.stats["denials"] == 1


def test_history_policy_learns_init():
    hist = HistoryStore()
    for _ in range(50):
        hist.observe("serve", "request", "pages", 6)
    pool = PagePool(1024, history=hist, policy="history")
    sz = pool.sizing()
    # a 6-page request must be covered within one scale-up (the solver may
    # legitimately prefer a small init + one large step: scaled allocations
    # are discounted by cost_factor in the paper's objective)
    import math
    k = math.ceil(max(6 - sz.init, 0) / max(sz.step, 1e-9))
    assert k <= 1, f"history of 6-page requests not covered cheaply: {sz}"


def test_engine_completes_all_requests():
    pool = PagePool(64, policy="fixed", fixed_init_pages=1)
    eng = ServingEngine(pool, max_batch=4)
    for i in range(10):
        eng.submit(Request(f"r{i}", prompt_len=16, max_new_tokens=8))
    stats = eng.run_to_completion()
    assert stats.completed == 10
    assert stats.tokens_generated == 80
    assert len(pool.free) == 64


def test_engine_preempts_on_pressure():
    # pool too small for 4 growing requests -> must preempt + still finish
    pool = PagePool(9, policy="fixed", fixed_init_pages=2, fixed_step_pages=1)
    eng = ServingEngine(pool, max_batch=4)
    for i in range(4):
        eng.submit(Request(f"r{i}", prompt_len=PAGE_SIZE * 2 - 4,
                           max_new_tokens=PAGE_SIZE))
    stats = eng.run_to_completion(max_steps=10_000)
    assert stats.completed == 4
    assert stats.preempted >= 1


def test_page_table_layout():
    rs = [Request("a", 1, 1), Request("b", 1, 1)]
    rs[0].pages = [3, 1]
    rs[1].pages = [2]
    pt = page_table(rs, 4)
    assert pt.shape == (2, 4)
    assert pt[0, 0] == 3 and pt[0, 1] == 1 and pt[1, 0] == 2
    assert (pt[0, 2:] == -1).all()


def test_pool_pages_for_budget():
    n = pool_pages_for_budget(16 << 30, num_layers=32, kv_dim=1024)
    assert n > 0
    # budget doubles -> pages double
    assert abs(pool_pages_for_budget(32 << 30, 32, 1024) - 2 * n) <= 1


def test_engine_with_real_model(rng):
    """Continuous batching driving a real tiny model decode loop."""
    cfg = reduced_config(get_config("tinyllama-1.1b"))
    model = build_model(cfg, ImplConfig(remat="none"))
    params = model.init_params(rng)
    cache_len = 64
    max_batch = 2
    cache = model.init_cache(max_batch, cache_len)
    decode = jax.jit(model.decode_step)

    state = {"pos": 0}

    def prefill_fn(req):
        pass  # tiny test: decode from scratch

    def decode_fn(running):
        toks = jnp.zeros((max_batch, 1), jnp.int32)
        logits, new_cache = decode(params, toks, state["cache"],
                                   jnp.asarray(state["pos"], jnp.int32))
        state["cache"] = new_cache
        state["pos"] += 1
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    state["cache"] = cache
    pool = PagePool(32, policy="fixed", fixed_init_pages=1)
    eng = ServingEngine(pool, max_batch=max_batch,
                        step_fns=(prefill_fn, decode_fn))
    for i in range(3):
        eng.submit(Request(f"r{i}", prompt_len=4, max_new_tokens=5))
    stats = eng.run_to_completion(max_steps=200)
    assert stats.completed == 3
