"""Serving stack: page pool sizing policies, continuous batching engine,
preemption, multi-tenant pool sharing, serving backends (dense vs paged),
and engine-with-real-model integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_config
from repro.configs import get_config
from repro.core.history import HistoryStore
from repro.models import ImplConfig, build_model
from repro.runtime import (Application, Cluster, JaxExecutor, NullExecutor,
                           ServeOptions)
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import (PAGE_SIZE, PageGroups, PagePool, Request,
                                    page_table, pool_pages_for_budget)
from repro.serving.tenancy import SharedPagePool


def test_pool_admit_grow_release():
    pool = PagePool(32, policy="fixed", fixed_init_pages=2, fixed_step_pages=1)
    r = Request("r", prompt_len=PAGE_SIZE * 3, max_new_tokens=PAGE_SIZE)
    assert pool.try_admit(r)
    assert len(r.pages) == 3
    r.generated = PAGE_SIZE  # outgrow
    assert pool.grow(r)
    assert len(r.pages) == 4
    pool.release(r)
    assert len(pool.free) == 32


def test_pool_denial_when_exhausted():
    pool = PagePool(4, policy="fixed", fixed_init_pages=4)
    r1 = Request("a", PAGE_SIZE, 1)
    r2 = Request("b", PAGE_SIZE, 1)
    assert pool.try_admit(r1)
    assert not pool.try_admit(r2)
    assert pool.stats["denials"] == 1


def test_history_policy_learns_init():
    hist = HistoryStore()
    for _ in range(50):
        hist.observe("serve", "request", "pages", 6)
    pool = PagePool(1024, history=hist, policy="history")
    sz = pool.sizing()
    # a 6-page request must be covered within one scale-up (the solver may
    # legitimately prefer a small init + one large step: scaled allocations
    # are discounted by cost_factor in the paper's objective)
    import math
    k = math.ceil(max(6 - sz.init, 0) / max(sz.step, 1e-9))
    assert k <= 1, f"history of 6-page requests not covered cheaply: {sz}"


def test_engine_completes_all_requests():
    pool = PagePool(64, policy="fixed", fixed_init_pages=1)
    eng = ServingEngine(pool, max_batch=4)
    for i in range(10):
        eng.submit(Request(f"r{i}", prompt_len=16, max_new_tokens=8))
    stats = eng.run_to_completion()
    assert stats.completed == 10
    assert stats.tokens_generated == 80
    assert len(pool.free) == 64


def test_engine_preempts_on_pressure():
    # pool too small for 4 growing requests -> must preempt + still finish
    pool = PagePool(9, policy="fixed", fixed_init_pages=2, fixed_step_pages=1)
    eng = ServingEngine(pool, max_batch=4)
    for i in range(4):
        eng.submit(Request(f"r{i}", prompt_len=PAGE_SIZE * 2 - 4,
                           max_new_tokens=PAGE_SIZE))
    stats = eng.run_to_completion(max_steps=10_000)
    assert stats.completed == 4
    assert stats.preempted >= 1


def test_grow_skips_requests_preempted_mid_pass():
    """Regression: the grow loop iterates a snapshot of ``running``; a
    request preempted mid-pass (its pages just released) must NOT get
    ``pool.grow()`` called on it afterward -- that granted pages to a
    queued request, which ``try_admit`` then overwrote on re-admission:
    a permanent page leak."""
    pool = PagePool(8, policy="fixed", fixed_init_pages=1, fixed_step_pages=1)
    eng = ServingEngine(pool, max_batch=4)
    # two "old" requests with staggered growth points...
    eng.submit(Request("old0", PAGE_SIZE * 2 - 8, 64))
    eng.submit(Request("old1", PAGE_SIZE * 2 - 30, 64))
    for _ in range(3):
        eng.step()
    # ...and a late 4-page request that becomes the preemption victim the
    # step old0 outgrows its grant (victim = least progress)
    eng.submit(Request("newbie", PAGE_SIZE * 4 - 8, 64))
    stats = eng.run_to_completion(max_steps=10_000)
    assert stats.completed == 3
    assert stats.preempted >= 1, "scenario must exercise mid-pass preemption"
    assert sorted(pool.free) == list(range(8)), \
        "pages leaked through grow-after-preempt"


def test_engine_latency_stats():
    pool = PagePool(64, policy="fixed", fixed_init_pages=1)
    eng = ServingEngine(pool, max_batch=4)
    for i in range(6):
        eng.submit(Request(f"r{i}", prompt_len=16, max_new_tokens=8))
    stats = eng.run_to_completion()
    assert stats.ttft_count == 6          # one first-token per request
    assert stats.mean_ttft_s >= 0.0
    d = stats.as_dict()
    assert "mean_ttft_s" in d and "mean_decode_step_s" in d
    # re-admission after preemption must not double-count TTFT
    pool2 = PagePool(9, policy="fixed", fixed_init_pages=2,
                     fixed_step_pages=1)
    eng2 = ServingEngine(pool2, max_batch=4)
    for i in range(4):
        eng2.submit(Request(f"p{i}", PAGE_SIZE * 2 - 4, PAGE_SIZE))
    s2 = eng2.run_to_completion(max_steps=10_000)
    assert s2.preempted >= 1
    assert s2.ttft_count == 4


def test_page_table_layout():
    rs = [Request("a", 1, 1), Request("b", 1, 1)]
    rs[0].pages = [3, 1]
    rs[1].pages = [2]
    pt = page_table(rs, 4)
    assert pt.shape == (2, 4)
    assert pt[0, 0] == 3 and pt[0, 1] == 1 and pt[1, 0] == 2
    assert (pt[0, 2:] == -1).all()


def test_pool_pages_for_budget():
    n = pool_pages_for_budget(16 << 30, num_layers=32, kv_dim=1024)
    assert n > 0
    # budget doubles -> pages double
    assert abs(pool_pages_for_budget(32 << 30, 32, 1024) - 2 * n) <= 1


# ---------------------------------------------------------------------------
# multi-tenant sharing: one pod-level pool, many apps (paper §9.3)
# ---------------------------------------------------------------------------

def test_shared_pool_two_apps_fair_preemption():
    """Two serve apps on one Cluster share ONE pod-level SharedPagePool;
    combined usage never exceeds the physical pool and the preemption
    victim comes from the app most over its fair share."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=14)
    a = cluster.submit(Application.serve("tinyllama-1.1b", reduced=True,
                                         name="app-a",
                                         serve=ServeOptions(max_batch=4)))
    b = cluster.submit(Application.serve("tinyllama-1.1b", reduced=True,
                                         name="app-b",
                                         serve=ServeOptions(max_batch=4)))
    shared = a.engine.pool.shared
    assert isinstance(shared, SharedPagePool)
    assert b.engine.pool.shared is shared, "one physical pool per pod"
    assert shared.num_pages == 14

    for i in range(4):                      # app-a grows into a page hog
        a.submit_request(Request(f"a{i}", PAGE_SIZE * 2 - 2, 300))
    for _ in range(3):
        a.step()
    assert a.engine.pool.used > shared.fair_share(a.engine.pool)

    for i in range(2):                      # app-b needs room to grow
        b.submit_request(Request(f"b{i}", PAGE_SIZE - 2, 8))
    for _ in range(6):
        b.step()
        combined = sum(v.used for v in shared.views.values())
        assert combined == shared.used_pages
        assert combined <= shared.num_pages, "over-committed physical pool"

    assert a.engine.stats.preempted >= 1, "victim must come from app-a"
    assert b.engine.stats.preempted == 0
    assert shared.stats["preemptions"].get("app-a", 0) >= 1
    assert shared.stats["cross_app_preemptions"] >= 1
    a.release()
    b.release()
    assert sorted(shared.free) == list(range(14)), "pages must be returned"
    assert not shared.views


def test_shared_pool_quota_enforced():
    cluster = Cluster(pods=1, executor=NullExecutor(), pool_pages=16)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="quota-app",
        serve=ServeOptions(max_batch=4, quota_pages=2)))
    h.submit_request(Request("small", PAGE_SIZE - 4, 4))
    big = Request("big", PAGE_SIZE * 3, 4)     # needs 4 pages > quota 2:
    h.submit_request(big)                      # can never complete
    view = h.engine.pool
    for _ in range(10):
        h.step()
        assert view.used <= 2, "quota must cap usage below the free pool"
    stats = h.serving_stats()
    assert stats["completed"] == 1                        # small finished
    assert stats["rejected"] == 1 and big.state == "rejected", \
        "an unservable request must be rejected, not retried forever"
    assert view.shared.stats["denials"].get("quota-app", 0) >= 1
    assert stats["shared_pool"]["denials_by_app"]["quota-app"] >= 1
    h.release()


def test_quota_pressure_does_not_preempt_cotenants():
    """A quota denial cannot be lifted by freeing co-tenants' pages: the
    over-quota app must shed its OWN load, not trigger cross-app
    preemption of innocent neighbours (regression: quota-bound growth
    preempted other apps and livelocked)."""
    cluster = Cluster(pods=1, executor=NullExecutor(), pool_pages=32)
    a = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="capped",
        serve=ServeOptions(max_batch=4, quota_pages=3)))
    b = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="bystander",
        serve=ServeOptions(max_batch=4)))
    for i in range(2):       # each needs 2 pages by completion; 4 > quota 3
        a.submit_request(Request(f"a{i}", PAGE_SIZE - 4, 132))
    for i in range(2):
        b.submit_request(Request(f"b{i}", PAGE_SIZE - 4, 300))
    for _ in range(3):
        b.step()             # bystander holds running requests throughout
    alive = True
    for _ in range(400):
        if not alive:
            break
        alive = a.step()["alive"]
    shared = a.engine.pool.shared
    assert a.serving_stats()["completed"] == 2   # sequentially, under quota
    assert a.engine.stats.preempted >= 1         # shed its own load
    assert b.engine.stats.preempted == 0, "bystander must not be preempted"
    assert shared.stats["cross_app_preemptions"] == 0
    a.release()
    b.release()


def test_duplicate_serve_names_rejected():
    """Two live serve apps with one name would merge their page accounting
    onto a single PoolView: the pod pool must refuse the second -- and the
    failed submit must not leak the placed job's pod bytes."""
    cluster = Cluster(pods=1, executor=NullExecutor(), pool_pages=16)
    cluster.submit(Application.serve("tinyllama-1.1b", reduced=True))
    cap1 = cluster.capacity()
    with pytest.raises(ValueError, match="unique name"):
        cluster.submit(Application.serve("tinyllama-1.1b", reduced=True))
    assert cluster.capacity() == cap1, "failed bind must release its job"


def test_policy_step_clamped_to_cap():
    """A sizing step/init larger than the quota (or pool) headroom must be
    clamped, not turned into a permanent denial: un-clamped, a servable
    request livelocks through admit/grow-deny/self-preempt forever."""
    shared = SharedPagePool(16)
    view = shared.view("clamped", quota=2, policy="fixed",
                       fixed_init_pages=1, fixed_step_pages=3)
    eng = ServingEngine(view, max_batch=2)
    eng.submit(Request("r", PAGE_SIZE - 4, 8))      # needs 2 pages total
    stats = eng.run_to_completion(max_steps=200)
    assert stats.completed == 1 and stats.rejected == 0

    pool = PagePool(2, policy="fixed", fixed_init_pages=1,
                    fixed_step_pages=5)             # step 5 > 2-page pool
    eng2 = ServingEngine(pool, max_batch=1)
    eng2.submit(Request("r2", PAGE_SIZE - 4, 8))
    s2 = eng2.run_to_completion(max_steps=200)
    assert s2.completed == 1 and s2.rejected == 0
    assert len(pool.free) == 2


def test_engine_rejects_request_larger_than_pool():
    pool = PagePool(4, policy="fixed", fixed_init_pages=1)
    eng = ServingEngine(pool, max_batch=4)
    eng.submit(Request("huge", PAGE_SIZE * 6, 8))   # 7 pages > 4-page pool
    eng.submit(Request("ok", PAGE_SIZE, 8))
    stats = eng.run_to_completion(max_steps=100)
    assert stats.rejected == 1
    assert stats.completed == 1


def test_private_pool_opt_out():
    cluster = Cluster(pods=1, executor=NullExecutor(), pool_pages=64)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="loner",
        serve=ServeOptions(private_pool=True, pool_pages=8)))
    assert isinstance(h.engine.pool, PagePool)
    assert not hasattr(h.engine.pool, "shared")
    assert not cluster.pod_pool("pod0").views     # nothing registered
    h.release()


# ---------------------------------------------------------------------------
# serving backends: DenseRunner vs PagedRunner (ModelRunner layer)
# ---------------------------------------------------------------------------

def _serve_tokens(backend: str, *, pool_pages=32, n=3, prompt=200,
                  max_new=6, policy="history", max_batch=4,
                  arch="tinyllama-1.1b", **opts):
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0))
    app = Application.serve(arch, reduced=True,
                            serve=ServeOptions(
                                max_batch=max_batch, pool_pages=pool_pages,
                                cache_len=512, policy=policy,
                                backend=backend, **opts))
    h = cluster.submit(app)
    reqs = [Request(f"r{i}", prompt_len=prompt, max_new_tokens=max_new)
            for i in range(n)]
    for r in reqs:
        h.submit_request(r)
    stats = h.run(max_steps=5000)
    # completed requests own their tokens (runner state is evicted)
    tokens = {r.req_id: list(r.output_tokens) for r in reqs
              if r.output_tokens is not None}
    h.release()
    return stats, tokens


def test_paged_runner_matches_dense_tokens():
    """backend='paged' (pool-page KV + paged-attention decode) must produce
    the SAME tokens as backend='dense' for the same seed."""
    dense_stats, dense_toks = _serve_tokens("dense")
    paged_stats, paged_toks = _serve_tokens("paged")
    assert dense_stats["completed"] == paged_stats["completed"] == 3
    assert dense_toks == paged_toks
    # multi-page prompts actually exercised the page tables
    assert all(len(t) == 7 for t in paged_toks.values())  # prefill + 6


def test_paged_backend_preemption_readmission():
    """Paged serving must survive preemption: pages released, request
    re-prefilled into fresh pages, decode correct thereafter."""
    # prompt 200 = 2 pages; growth past token 256 with a full 8-page pool
    # forces preemption + re-prefill into different physical pages
    stats, tokens = _serve_tokens("paged", pool_pages=8, n=4, prompt=200,
                                  max_new=60, policy="fixed")
    assert stats["preempted"] >= 1, "scenario must exercise preemption"
    assert stats["completed"] == 4
    assert all(len(t) == 61 for t in tokens.values())


def test_paged_backend_rejects_unsupported_arch():
    from repro.serving.model_runner import build_runner
    cfg = reduced_config(get_config("zamba2-2.7b"))  # mamba/shared blocks
    with pytest.raises(ValueError, match="paged"):
        build_runner("paged", cfg)
    with pytest.raises(ValueError, match="backend"):
        build_runner("sparse", reduced_config(get_config("tinyllama-1.1b")))


def test_failed_bind_leaks_neither_job_nor_pool_view():
    """A bind that fails after the pool view is registered must close the
    view (an orphan would dilute fair shares forever) AND release the
    placed job's pod bytes."""
    cluster = Cluster(pods=1, executor=JaxExecutor(), pool_pages=12)
    cap0 = cluster.capacity()
    with pytest.raises(ValueError, match="backend"):
        cluster.submit(Application.serve(
            "tinyllama-1.1b", reduced=True, name="bad",
            serve=ServeOptions(backend="sparse")))
    assert not cluster.pod_pool("pod0").views, "orphan PoolView left behind"
    assert cluster.capacity() == cap0


def test_page_groups_ring_accounting():
    """Unit-level group accounting: local (ring) pages stop charging past
    ``ceil(window/PAGE_SIZE)+1`` while the global table keeps growing,
    and release returns both id spaces intact."""
    cfg = reduced_config(get_config("gemma3-12b"))    # 5 local : 1 global
    groups = PageGroups.from_config(cfg)
    assert groups.local_layers == 5 and groups.global_layers == 1
    ring = groups.ring_pages
    assert ring == -(-cfg.sliding_window // PAGE_SIZE) + 1
    pool = PagePool(32, policy="fixed", fixed_init_pages=1,
                    fixed_step_pages=1, groups=groups)
    r = Request("r", prompt_len=PAGE_SIZE, max_new_tokens=PAGE_SIZE * 8)
    assert pool.try_admit(r)
    assert len(r.pages) == 1 and len(r.local_pages) == 1
    for step in range(8):                      # grow one page at a time
        r.generated += PAGE_SIZE
        assert pool.grow(r, horizon=1)
        assert len(r.local_pages) <= ring, \
            "ring must stop charging pages past ceil(window/PAGE)+1"
    assert len(r.pages) == r.pages_needed(1) > ring
    assert len(r.local_pages) == ring
    # weighted utilization reflects the bounded rings, not the table
    assert pool.utilization < len(r.pages) / pool.num_pages
    pool.release(r)
    assert sorted(pool.free) == list(range(32))
    assert sorted(pool.free_local) == list(range(32))


def test_paged_swa_matches_dense_tokens():
    """Mixed global/sliding-window stack (reduced gemma3): the paged
    backend's ring pages must produce the SAME tokens as the dense
    backend, including after the generation wraps the ring (length
    past ring_pages * PAGE_SIZE)."""
    dense_stats, dense_toks = _serve_tokens("dense", arch="gemma3-12b",
                                            n=2, prompt=200, max_new=70)
    paged_stats, paged_toks = _serve_tokens("paged", arch="gemma3-12b",
                                            n=2, prompt=200, max_new=70)
    assert dense_stats["completed"] == paged_stats["completed"] == 2
    assert dense_toks == paged_toks
    assert all(len(t) == 71 for t in paged_toks.values())


def test_paged_swa_ring_and_no_ring_tokens_identical():
    """swa_rings=False (the benchmark's accounting baseline) keeps
    decode windowed and token-identical; only the page charge differs."""
    _, ring_toks = _serve_tokens("paged", arch="gemma3-12b", n=2,
                                 prompt=200, max_new=70)
    _, flat_toks = _serve_tokens("paged", arch="gemma3-12b", n=2,
                                 prompt=200, max_new=70, swa_rings=False)
    assert ring_toks == flat_toks


def test_swa_ring_page_cap_long_generation():
    """A long-generation request on a sliding-window stack holds at most
    ``ring_pages`` pages on local layers while its global table grows
    past them -- the acceptance bound of the ring design."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0))
    h = cluster.submit(Application.serve(
        "gemma3-12b", reduced=True,
        serve=ServeOptions(max_batch=2, pool_pages=32, backend="paged",
                           policy="fixed")))
    ring = h.runner.groups.ring_pages
    req = Request("long", prompt_len=64, max_new_tokens=PAGE_SIZE * 3)
    h.submit_request(req)
    peak_local = peak_global = 0
    while h.step()["alive"]:
        peak_local = max(peak_local, len(req.local_pages))
        peak_global = max(peak_global, len(req.pages))
    assert peak_local <= ring
    assert peak_global > ring, "scenario must outgrow the ring"
    assert h.serving_stats()["completed"] == 1
    view = h.engine.pool
    assert view.used == 0 and view.used_local == 0
    h.release()


def test_paged_prefill_has_no_dense_detour():
    """Native paged prefill: the runner must never call the model's
    dense ``prefill(cache_len=...)`` path (the per-grant-size recompile
    plus transient ``n_pages * PAGE_SIZE`` allocation it existed for)."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0))
    h = cluster.submit(Application.serve(
        "gemma3-12b", reduced=True,
        serve=ServeOptions(max_batch=2, pool_pages=32, backend="paged")))

    def boom(*a, **k):
        raise AssertionError("dense model.prefill called by PagedRunner")

    h.runner.model.prefill = boom
    h.submit_request(Request("r0", 200, 8))
    stats = h.run(max_steps=500)
    assert stats["completed"] == 1
    h.release()


@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_runner_state_evicted_on_completion(backend):
    """Long-run leak regression: per-request runner state (generated
    token lists, dense slots) must be evicted when requests complete --
    the tokens move to ``req.output_tokens``."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0))
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True,
        serve=ServeOptions(max_batch=4, pool_pages=32, cache_len=512,
                           backend=backend)))
    reqs = [Request(f"r{i}", 40, 5) for i in range(6)]
    for r in reqs:
        h.submit_request(r)
    stats = h.run(max_steps=2000)
    assert stats["completed"] == 6
    assert h.runner.generated == {}, \
        "completed requests must not accumulate in runner.generated"
    if backend == "dense":
        assert h.runner.slots == {}, "dense slots must drain too"
    assert all(len(r.output_tokens) == 6 for r in reqs)
    h.release()


def test_paged_decode_compile_count_is_bounded():
    """Bursty batches must NOT recompile decode per (batch, max_pages)
    shape: the batch is padded to max_batch and the table width is
    bucketed, so a run with varying running-set sizes triggers O(1)
    compiles, not O(steps)."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0))
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True,
        serve=ServeOptions(max_batch=4, pool_pages=64, backend="paged")))
    # batch size varies every few steps: 1 -> 3 -> 4 -> shrink as they
    # finish; page grants vary with prompt length
    h.submit_request(Request("a", 40, 30))
    for _ in range(5):
        h.step()
    h.submit_request(Request("b", 200, 30))
    h.submit_request(Request("c", 330, 30))
    for _ in range(8):
        h.step()
    h.submit_request(Request("d", 64, 40))
    stats = h.run(max_steps=2000)
    assert stats["completed"] == 4
    assert stats["decode_steps"] > 30
    assert h.runner.decode_traces <= 3, \
        f"decode recompiled {h.runner.decode_traces}x under bursty load"
    # prefill compiles per prompt-page-count bucket, not per grant size
    assert h.runner.prefill_traces <= 3
    h.release()


def test_engine_with_real_model(rng):
    """Continuous batching driving a real tiny model decode loop."""
    cfg = reduced_config(get_config("tinyllama-1.1b"))
    model = build_model(cfg, ImplConfig(remat="none"))
    params = model.init_params(rng)
    cache_len = 64
    max_batch = 2
    cache = model.init_cache(max_batch, cache_len)
    decode = jax.jit(model.decode_step)

    state = {"pos": 0}

    def prefill_fn(req):
        pass  # tiny test: decode from scratch

    def decode_fn(running):
        toks = jnp.zeros((max_batch, 1), jnp.int32)
        logits, new_cache = decode(params, toks, state["cache"],
                                   jnp.asarray(state["pos"], jnp.int32))
        state["cache"] = new_cache
        state["pos"] += 1
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    state["cache"] = cache
    pool = PagePool(32, policy="fixed", fixed_init_pages=1)
    eng = ServingEngine(pool, max_batch=max_batch,
                        step_fns=(prefill_fn, decode_fn))
    for i in range(3):
        eng.submit(Request(f"r{i}", prompt_len=4, max_new_tokens=5))
    stats = eng.run_to_completion(max_steps=200)
    assert stats.completed == 3
