"""Global prefix cache: radix-trie lookup, refcounted copy-on-write
pages, suffix-only chunked prefill.

Two layers:

* pure trie (no jax): the PR's edge-case checklist -- empty prompt,
  sub-page prompt, divergence exactly at a full-page boundary (plain
  miss, no COW), two requests racing to insert the same prefix in one
  tick (second adopts nothing), refcount-0 LRU eviction that never takes
  pinned pages, and a hypothesis property (lookup of any probe against
  an inserted prompt only ever matches a true common prefix);
* jax integration: warm-vs-cold-vs-dense token exactness, chunked
  prefill parity on long prompts without a cache, the dense backend
  rejecting ``prefix_cache=True`` loudly, pod accounting (cache pages
  out of view quota but inside pod used_pages), eviction under co-tenant
  pressure with mid-decode pins held, and park/unpark re-attach
  (surviving prefix nodes re-pinned; evicted ones -> requeue-recompute,
  tokens identical either way).
"""

import pytest

from repro.core.history import HistoryStore
from repro.runtime import Application, Cluster, JaxExecutor, ServeOptions
from repro.serving.kv_cache import PAGE_SIZE, Request
from repro.serving.prefix_cache import PrefixCache
from repro.serving.tenancy import SharedPagePool


def _cache(freed=None):
    freed = freed if freed is not None else []
    return PrefixCache(("test",), freed.extend), freed


def _toks(n, seed=0):
    return tuple((seed * 7919 + i * 31) % 211 for i in range(n))


# ---------------------------------------------------------------------------
# pure trie
# ---------------------------------------------------------------------------

def test_empty_prompt_is_a_miss_and_inserts_nothing():
    cache, _ = _cache()
    m = cache.pin(())
    assert not m.hit and m.cached_len == 0 and m.nodes == []
    assert cache.unpin(m.nodes) == 0
    assert cache.probe_new((), 0) == (0, False)
    assert cache.num_pages == 0


def test_prompt_shorter_than_one_page_round_trips_as_partial():
    cache, _ = _cache()
    toks = _toks(PAGE_SIZE // 2)
    assert cache.pin(toks).cached_len == 0
    n_new, partial_new = cache.probe_new(toks, 0)
    assert (n_new, partial_new) == (0, True)
    created = cache.insert(toks, 0, [], partial_page=7)
    assert len(created) == 1 and not created[0].full
    m = cache.pin(toks)
    # sub-page content is a COW source, never a table-ready full page
    assert m.cached_len == len(toks) and m.phys_pages == []
    assert m.cow_src == 7
    cache.unpin(created + m.nodes)


def test_divergence_at_exact_page_boundary_is_a_plain_miss():
    cache, _ = _cache()
    base = _toks(2 * PAGE_SIZE)
    donor = cache.insert(base, 0, [10, 11])
    # agrees on page 0, diverges at EXACTLY the page-1 boundary: one full
    # page matches, and there is no COW source (no partial content)
    probe = base[:PAGE_SIZE] + _toks(PAGE_SIZE, seed=99)
    m = cache.pin(probe)
    assert m.cached_len == PAGE_SIZE
    assert m.phys_pages == [10]
    assert m.cow_src is None
    cache.unpin(donor + m.nodes)


def test_divergence_inside_partial_page_yields_cow_lead():
    cache, _ = _cache()
    base = _toks(PAGE_SIZE + 40)
    donor = cache.insert(base, 0, [3], partial_page=4)
    probe = base[:PAGE_SIZE + 25] + _toks(60, seed=5)
    m = cache.pin(probe)
    assert m.phys_pages == [3]
    assert m.cached_len == PAGE_SIZE + 25     # lead slots via COW
    assert m.cow_src == 4
    cache.unpin(donor + m.nodes)


def test_racing_inserts_second_adopts_nothing():
    """Two requests with the SAME prompt admitted in one tick: both miss
    at pin time; the first insert wins, the second probe sees the trie
    moved past its attach depth and adopts zero pages (its donation
    would not extend its own matched prefix contiguously)."""
    cache, _ = _cache()
    toks = _toks(2 * PAGE_SIZE + 30)
    m0, m1 = cache.pin(toks), cache.pin(toks)
    assert not m0.hit and not m1.hit
    assert cache.probe_new(toks, 0) == (2, True)
    created = cache.insert(toks, 0, [20, 21], partial_page=22)
    assert cache.probe_new(toks, 0) == (0, False), "raced insert adopts 0"
    # and a third request pinning NOW simply hits the winner's pages
    m2 = cache.pin(toks)
    assert m2.phys_pages == [20, 21] and m2.cached_len == len(toks)
    cache.unpin(created + m2.nodes)


def test_eviction_is_refcount0_lru_and_never_takes_pins():
    cache, freed = _cache()
    a = cache.insert(_toks(PAGE_SIZE), 0, [1])
    b = cache.insert(_toks(PAGE_SIZE, seed=2), 0, [2])
    assert cache.peek_evictable() is None, "pinned nodes are not candidates"
    assert cache.evict_lru(need=4) == 0 and freed == []
    cache.unpin(a)                       # a older than b, both now refs=0
    cache.unpin(b)
    assert cache.evict_lru(need=1) == 1
    assert freed == [1], "LRU order: the older unpinned node goes first"
    assert cache.evict_lru(need=8) == 1 and freed == [1, 2]
    assert cache.num_pages == 0


def test_interior_nodes_survive_until_leaves_go():
    cache, freed = _cache()
    chain = cache.insert(_toks(2 * PAGE_SIZE + 10), 0, [5, 6],
                         partial_page=7)
    cache.unpin(chain)
    # leaf-first: partial 7, then page-1 node 6, then the root child 5
    cache.evict_lru(need=3)
    assert freed == [7, 6, 5]


def test_shared_take_global_lru_across_pod_caches_behind_pins():
    """``SharedPagePool._take`` under pool pressure: refcount-0 leaves
    sitting BEHIND pinned chain heads are the only victims, taken
    oldest-first ACROSS both pod caches; the pinned heads themselves
    are never evicted, so a shortfall bigger than the evictable tail
    is denied rather than satisfied by stealing pins."""
    shared = SharedPagePool(8, history=HistoryStore())
    ca = shared.prefix_cache(("a",),
                             lambda: PrefixCache(("a",), shared._give))
    cb = shared.prefix_cache(("b",),
                             lambda: PrefixCache(("b",), shared._give))
    toks = _toks(2 * PAGE_SIZE)
    chain_a = ca.insert(toks, 0, shared._take(2))
    chain_b = cb.insert(toks, 0, shared._take(2))
    # chain heads stay pinned (in-flight requests decode through them);
    # the leaves drop to refcount 0.  A later lookup re-touches a's
    # chain, so b's leaf is the globally least-recently-used candidate.
    cb.unpin([chain_b[1]])
    ca.unpin([chain_a[1]])
    m = ca.pin(toks)
    ca.unpin(m.nodes)
    assert len(shared.free) == 4
    got = shared._take(5)                 # shortfall of 1: evict ONE page
    assert got is not None and len(got) == 5
    assert chain_b[1] not in cb.nodes, "global LRU: b's older leaf first"
    assert chain_a[1] in ca.nodes, "a's younger leaf must survive"
    assert shared.stats["prefix_evictions"] == 1
    shared._give(got)
    got = shared._take(6)                 # next shortfall: a's leaf goes
    assert got is not None
    assert chain_a[1] not in ca.nodes
    assert shared.stats["prefix_evictions"] == 2
    shared._give(got)
    # only the two pinned heads remain cached: a demand beyond the
    # evictable tail is DENIED and the pins are untouched
    assert shared._take(7) is None
    assert chain_a[0] in ca.nodes and chain_b[0] in cb.nodes
    assert chain_a[0].refs == 1 and chain_b[0].refs == 1
    # once the in-flight pins release, the heads become ordinary
    # refcount-0 candidates and the full pool is reclaimable
    ca.unpin([chain_a[0]])
    cb.unpin([chain_b[0]])
    got = shared._take(8)
    assert got is not None and len(got) == 8
    assert ca.num_pages == 0 and cb.num_pages == 0
    shared._give(got)


def test_flush_leaves_pinned_nodes_alone():
    cache, freed = _cache()
    keep = cache.insert(_toks(PAGE_SIZE), 0, [1])
    drop = cache.insert(_toks(PAGE_SIZE, seed=3), 0, [2])
    cache.unpin(drop)
    assert cache.flush() == 1 and freed == [2]
    assert cache.num_pages == 1
    cache.unpin(keep)
    assert cache.flush() == 1 and freed == [2, 1]


def test_lookup_of_inserted_prompt_matches_a_true_prefix():
    """Hypothesis property: after inserting prompt ``p``, pinning any
    probe ``q`` yields cached tokens that are a common prefix of BOTH --
    the cache may only ever hand back KV for tokens the request actually
    has."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.given(
        p=st.lists(st.integers(0, 7), max_size=3 * PAGE_SIZE + 9),
        q=st.lists(st.integers(0, 7), max_size=3 * PAGE_SIZE + 9))
    @hypothesis.settings(max_examples=60, deadline=None)
    def prop(p, q):
        cache, _ = _cache()
        n_full, rem = len(p) // PAGE_SIZE, len(p) % PAGE_SIZE
        created = cache.insert(p, 0, list(range(n_full)),
                               partial_page=n_full if rem else None)
        m = cache.pin(q)
        assert m.cached_len <= len(q)
        assert tuple(q[:m.cached_len]) == tuple(p[:m.cached_len])
        # full coverage when the probe IS the prompt
        m2 = cache.pin(p)
        assert m2.cached_len == len(p)
        cache.unpin(created + m.nodes + m2.nodes)

    prop()


# ---------------------------------------------------------------------------
# jax integration (reduced model through the runtime)
# ---------------------------------------------------------------------------

def _overlap_requests(n, *, shared_len=2 * PAGE_SIZE + 25, suffix_len=70,
                      gen=6):
    shared = _toks(shared_len, seed=1)
    reqs = []
    for i in range(n):
        toks = shared + _toks(suffix_len, seed=100 + i)
        reqs.append(Request(f"px{i}", len(toks), gen, prompt_tokens=toks))
    return reqs


def _mk_handle(cluster, name, *, backend="paged", prefix=False, **opts):
    return cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name=name,
        serve=ServeOptions(max_batch=2, backend=backend, policy="fixed",
                           cache_len=1024, prefix_cache=prefix, **opts)))


def _serve_seq(h, reqs):
    """One request at a time (deterministic hit pattern: no insert race)."""
    out = []
    for r in reqs:
        h.submit_request(r)
        while h.step()["alive"]:
            pass
        out.append(tuple(r.output_tokens))
    return out


def test_warm_cold_dense_token_exactness():
    """The tentpole acceptance: cached (warm), uncached paged (cold) and
    dense prefill produce IDENTICAL tokens for >=50%-overlap prompts --
    reusing cached prefix KV and copy-on-write partial pages changes
    which pages prefill computes, never the tokens."""
    outs, stats = {}, {}
    for arm, (backend, prefix) in (("warm", ("paged", True)),
                                   ("cold", ("paged", False)),
                                   ("dense", ("dense", False))):
        cluster = Cluster(pods=1, history=HistoryStore(),
                          executor=JaxExecutor(seed=0), pool_pages=64)
        h = _mk_handle(cluster, f"parity-{arm}", backend=backend,
                       prefix=prefix)
        outs[arm] = _serve_seq(h, _overlap_requests(3))
        stats[arm] = h.serving_stats()
        h.release()
    assert outs["warm"] == outs["cold"] == outs["dense"]
    s = stats["warm"]
    assert s["prefix_hit_rate"] == pytest.approx(2 / 3)
    assert s["cow_copies"] > 0, "mid-page overlap must exercise COW"
    assert s["shared_pages"] > 0
    # suffix-only prefill actually skipped the cached pages
    assert (s["prefill_pages_computed"]
            < stats["cold"]["prefill_pages_computed"])


def test_chunked_prefill_matches_dense_on_long_prompts():
    """PR 4 follow-up: prompts longer than one chunk run fixed-size
    chunked prefill even with no cache -- token parity with dense and a
    bounded trace count (chunks reuse one compiled shape per bucket)."""
    longreqs = lambda: [Request(f"lg{i}", 5 * PAGE_SIZE + 17, 5,
                                prompt_tokens=_toks(5 * PAGE_SIZE + 17,
                                                    seed=40 + i))
                        for i in range(2)]
    outs = {}
    for backend in ("paged", "dense"):
        cluster = Cluster(pods=1, history=HistoryStore(),
                          executor=JaxExecutor(seed=0), pool_pages=64)
        h = _mk_handle(cluster, f"chunk-{backend}", backend=backend)
        outs[backend] = _serve_seq(h, longreqs())
        if backend == "paged":
            assert h.runner.prefill_traces <= 3, \
                "chunked prefill must bucket, not retrace per prompt"
        h.release()
    assert outs["paged"] == outs["dense"]


def test_dense_backend_rejects_prefix_cache():
    """Dense KV has no page identity to share: asking for the prefix
    cache must fail loudly, not silently serve uncached.  The typed API
    now rejects the combination at ServeOptions construction -- before
    any bind, so no pool view can leak; build_runner keeps its own
    defense-in-depth check for direct callers."""
    from repro.configs import get_config
    from repro.configs.reduced import reduced_config
    from repro.serving.model_runner import build_runner

    with pytest.raises(ValueError, match="no shareable page identity"):
        build_runner("dense", reduced_config(get_config("tinyllama-1.1b")),
                     prefix_cache=PrefixCache(("x",), lambda pages: None))

    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=32)
    with pytest.raises(ValueError, match="page identity"):
        _mk_handle(cluster, "dense-reject", backend="dense", prefix=True)
    assert not cluster.pod_pool("pod0").views, \
        "failed construction leaked a pool view"


def test_cache_pages_out_of_quota_but_in_pod_accounting():
    """Donated pages leave the view's quota charge (suffix-only admits
    cheaper) but stay in pod used_pages/utilization -- they are not
    free, they are cache-owned."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=64)
    h = _mk_handle(cluster, "quota", prefix=True)
    _serve_seq(h, _overlap_requests(2))
    pool = h.engine.pool
    shared = pool.shared
    cache = h.runner.prefix
    assert cache.num_pages > 0
    assert pool.used == 0, "completed requests must release private pages"
    assert shared.used_pages == cache.num_pages, \
        "cache-owned pages stay charged at pod level"
    util_with_cache = shared.utilization
    assert util_with_cache > 0
    h.release()
    assert shared.used_pages == 0, \
        "last user's release flushes the cache with its arrays"


def test_eviction_under_cotenant_pressure_holds_pins():
    """A co-tenant draining the pod free list forces refcount-0 LRU
    eviction of cache pages -- but never of a chain the cached tenant is
    decoding through, so its tokens stay exact under pressure."""
    def run(pressure):
        # 14 pages: the greedy tenant's two CONCURRENT 6-page requests
        # overshoot the free list left by the pinned cache chain + the
        # mid-decode request (forcing refcount-0 eviction), while any
        # single request still fits once a peer completes (no livelock)
        cluster = Cluster(pods=1, history=HistoryStore(),
                          executor=JaxExecutor(seed=0), pool_pages=14)
        a = _mk_handle(cluster, "cached-a", prefix=True)
        outs = _serve_seq(a, _overlap_requests(2, gen=4))
        evicted = 0
        if pressure:
            shared = cluster.pod_pool("pod0")
            assert shared.used_pages > 0      # idle cache pages held
            b = _mk_handle(cluster, "greedy-b", quota_pages=14)
            # interleave: a decodes through pinned prefix pages while
            # b's grants squeeze the free list
            ra = _overlap_requests(1, gen=8)[0]
            ra.req_id = "under-pressure"
            a.submit_request(ra)
            a.step()                          # pin + prefill, mid-decode
            for big in [Request(f"big{i}", 5 * PAGE_SIZE, 4)
                        for i in range(3)]:
                b.submit_request(big)         # batched: demand > free
            while b.step()["alive"]:
                pass
            while a.step()["alive"]:
                pass
            outs.append(tuple(ra.output_tokens))
            evicted = shared.stats["prefix_evictions"]
            b.release()
        else:
            ra = _overlap_requests(1, gen=8)[0]
            ra.req_id = "under-pressure"
            outs.extend(_serve_seq(a, [ra]))
        a.release()
        return outs, evicted

    calm, _ = run(False)
    pressured, evicted = run(True)
    assert evicted > 0, "co-tenant demand must reclaim idle cache pages"
    assert pressured == calm, "pinned prefix pages must hold mid-decode"


def test_park_unpark_reattaches_or_recomputes():
    """Parking snapshots only private pages; unpark re-pins the same
    prefix chain when it survived, and falls back to requeue-recompute
    when the cache was flushed meanwhile -- token-identical either way."""
    def run(disturb):
        cluster = Cluster(pods=1, history=HistoryStore(),
                          executor=JaxExecutor(seed=0), pool_pages=64)
        h = _mk_handle(cluster, "parker", prefix=True)
        # a live same-model co-tenant keeps the pod's KV arrays (and with
        # them the cache content) alive across the park; a SOLE tenant's
        # park flushes the cache with the arrays, so reattach is only
        # reachable in co-tenancy
        keeper = _mk_handle(cluster, "keeper", prefix=True)
        warm = _overlap_requests(2, gen=4)
        out = _serve_seq(h, warm[:1])
        r = warm[1]
        h.submit_request(r)
        h.step()                              # hit + prefill, mid-decode
        assert r.shared_pages, "second overlapping request must hit"
        h.park()
        if disturb == "flush":
            cache = h.runner.prefix
            assert cache.flush() > 0, "parked pins must be dropped"
        receipt = h.unpark()
        if disturb == "flush":
            assert receipt["requeued_requests"] == 1
        else:
            assert receipt["restored_requests"] == 1
            assert h.runner.reattach_unpins == 0
        while h.step()["alive"]:
            pass
        out.append(tuple(r.output_tokens))
        h.release()
        keeper.release()
        return out

    assert run(None) == run("flush")
